//! Pluggable synchronization topologies — who exchanges outer gradients
//! with whom, each round.
//!
//! DiLoCo's Algorithm 1 is a **star**: every island ships its outer
//! gradient to one coordinator, which averages and broadcasts. Follow-up
//! work replaces that reduction without touching the inner loop:
//! NoLoCo (arXiv:2506.10911) uses dynamic pairwise **gossip** averaging
//! with no coordinator at all, and DiLoCoX (arXiv:2506.21263) stacks a
//! two-level **hierarchical** sync for decentralized clusters. This
//! module makes the reduction a pluggable axis: a [`Topology`] yields,
//! per round, a deterministic set of directed [`Transfer`]s (what the
//! [`super::SimNet`] bills) plus a row-stochastic mixing matrix (what
//! the replicas average).
//!
//! Four implementations ship:
//!
//! * [`Star`] — all-to-coordinator with §6.1 weights; one global model
//!   replica. The coordinator's hot path *is* this schedule, kept
//!   bitwise-identical to the pre-topology loop.
//! * [`Ring`] — a bandwidth-optimal ring all-reduce: `2(k−1)` hops of
//!   `1/k`-sized chunks, all k lanes busy every hop. Every replica ends
//!   with the same (full, weighted) average; state is per-replica.
//! * [`Gossip`] — seeded random pairwise exchanges à la NoLoCo: each
//!   round a fresh seeded permutation pairs the islands, each pair
//!   averages, unpaired islands keep their own gradient.
//! * [`Hierarchical`] — intra-group star onto a group leader, then an
//!   inter-group star onto the root, à la DiLoCoX. Intra-group hops ride
//!   free datacenter links; only leader ↔ root hops cross the billed
//!   WAN, so the root sees `G` flows instead of `k`.
//!
//! **Determinism contract** (extends DESIGN.md §4): a topology's
//! transfer schedule and mixing matrix are pure functions of
//! `(topology config, seed, round, k)` — never of execution order or
//! delivery timing. Gossip's pairing derives from a per-round child of
//! the run seed; drop decisions stay keyed, now on
//! `(fabric seed, round, worker, fragment, hop)` via
//! [`super::SimNet::try_send_hop`], with hop 0 reproducing the legacy
//! key so star traces are unchanged bitwise.
//!
//! # Examples
//!
//! A gossip round is a deterministic pairing — same seed and round, same
//! pairs, in any call order:
//!
//! ```
//! use diloco::comm::topology::Gossip;
//!
//! let topo = Gossip { seed: 7 };
//! let a = topo.pairs(3, 8);
//! let b = topo.pairs(3, 8);
//! assert_eq!(a, b);           // pure in (seed, round, k)
//! assert_eq!(a.len(), 4);     // 8 islands -> 4 disjoint pairs
//! ```
//!
//! Mixing matrices are row-stochastic once normalized:
//!
//! ```
//! use diloco::comm::topology::{row_stochastic, Gossip, Topology};
//!
//! let topo = Gossip { seed: 0 };
//! let w = vec![1.0; 4];
//! let raw = topo.mixing_raw(0, 4, &w, &[true; 4]);
//! for row in row_stochastic(&raw) {
//!     assert!((row.iter().sum::<f64>() - 1.0).abs() < 1e-12);
//! }
//! ```

use super::Direction;
use crate::util::math;
use crate::util::rng::Rng;

/// Hop index of a worker's first-hop upload — the legacy drop key.
pub const HOP_UPLOAD: usize = 0;
/// Hop index of a hierarchical group leader's aggregate upload to the
/// root coordinator (the droppable WAN hop of [`Hierarchical`]).
pub const HOP_LEADER_UP: usize = 1;

/// An endpoint of a [`Transfer`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Node {
    /// A training island (worker id).
    Worker(usize),
    /// The root coordinator (star and hierarchical only).
    Hub,
}

/// One directed hop of a round's synchronization schedule.
///
/// `lane = Some(w)` bills the transfer on worker `w`'s WAN link through
/// the existing [`super::SimNet`] lane machinery (messages on one lane
/// serialize, distinct lanes overlap); `lane = None` marks a free local
/// hop (hierarchical intra-group links, which the WAN model does not
/// bill). Droppable transfers are keyed on
/// `(fabric seed, round, sender, fragment, hop)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transfer {
    pub from: Node,
    pub to: Node,
    /// Worker whose WAN link carries the bytes; `None` = free local hop.
    pub lane: Option<usize>,
    pub dir: Direction,
    /// Worker whose outer-gradient contribution rides this transfer —
    /// the drop key's worker component, and the replica excluded from
    /// receivers' mixing rows when the transfer drops.
    pub sender: usize,
    /// Hop index within the round (drop-key component).
    pub hop: usize,
    /// Keyed-droppable (`true`) vs reliable (`false`).
    pub droppable: bool,
    /// `Some((c, of))`: the transfer carries near-equal chunk `c` of
    /// `of` of the fragment payload (ring hops); `None`: the whole
    /// fragment payload.
    pub chunk: Option<(usize, usize)>,
}

/// A synchronization topology: the per-round transfer schedule plus the
/// mixing matrix that turns per-worker outer gradients into per-replica
/// updates.
///
/// Centralized topologies ([`Star`], [`Hierarchical`]) keep one global
/// model replica (`n_replicas = 1`); decentralized topologies ([`Ring`],
/// [`Gossip`]) keep one replica — model plus outer-optimizer state — per
/// worker.
pub trait Topology: Send + Sync {
    /// Stable name (config / report label).
    fn name(&self) -> &'static str;

    /// `true` when every worker keeps its own model replica and outer
    /// state; `false` when a single global replica exists.
    fn is_decentralized(&self) -> bool;

    /// Independent model replicas maintained for `k` workers.
    fn n_replicas(&self, k: usize) -> usize {
        if self.is_decentralized() {
            k
        } else {
            1
        }
    }

    /// The deterministic, ordered transfer schedule for `round` over `k`
    /// active workers. Download transfers of centralized topologies are
    /// declared unconditionally; the coordinator only executes them for
    /// workers whose upload landed.
    fn transfers(&self, round: usize, k: usize) -> Vec<Transfer>;

    /// Raw (unnormalized) mixing rows, one per replica: entry `[r][j]`
    /// is the weight replica `r` gives worker `j`'s outer gradient.
    /// `weights` are the §6.1 per-worker averaging weights and
    /// `landed[j]` says whether worker `j`'s outgoing contribution was
    /// delivered. Rows normalize to the row-stochastic mixing matrix
    /// (see [`row_stochastic`]); consumers feed the raw rows to
    /// [`crate::coordinator::aggregate::WeightedMean`] (or a robust
    /// `[aggregate]` estimator), whose default path normalizes with the
    /// same scalar operations as the monolithic star average — keeping
    /// star bitwise-stable.
    fn mixing_raw(
        &self,
        round: usize,
        k: usize,
        weights: &[f64],
        landed: &[bool],
    ) -> Vec<Vec<f64>>;

    /// The row-stochastic mixing matrix (normalized [`Self::mixing_raw`]).
    fn mixing_matrix(
        &self,
        round: usize,
        k: usize,
        weights: &[f64],
        landed: &[bool],
    ) -> Vec<Vec<f64>> {
        row_stochastic(&self.mixing_raw(round, k, weights, landed))
    }
}

/// Normalize raw mixing rows so each row sums to 1 (all-zero rows stay
/// zero — a replica that received nothing mixes nothing).
pub fn row_stochastic(rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
    rows.iter()
        .map(|row| {
            // Row totals feed replica mixing weights — audited
            // order-pinned sum (D4), bitwise-identical fold.
            let s = math::sum_f64(row);
            row.iter()
                .map(|&x| if s > 0.0 { x / s } else { 0.0 })
                .collect()
        })
        .collect()
}

/// Elements in near-equal chunk `c` of `of` over `n` elements — the
/// size of flat range `[c·n/of, (c+1)·n/of)`, exactly as
/// [`crate::comm::fragment::FragmentPlan`] splits fragments. Ring hops
/// and their analytic byte formulas both use this, so billed and
/// expected bytes agree to the byte.
pub fn chunk_elems(n: usize, c: usize, of: usize) -> usize {
    (c + 1) * n / of - c * n / of
}

/// DiLoCo's star: every worker uploads to the hub (droppable, legacy
/// hop-0 key), the hub broadcasts fresh parameters back.
pub struct Star;

impl Topology for Star {
    fn name(&self) -> &'static str {
        "star"
    }

    fn is_decentralized(&self) -> bool {
        false
    }

    fn transfers(&self, _round: usize, k: usize) -> Vec<Transfer> {
        if k <= 1 {
            return Vec::new(); // k = 1: local outer step, nothing crosses the fabric
        }
        let mut out = Vec::with_capacity(2 * k);
        for w in 0..k {
            out.push(Transfer {
                from: Node::Worker(w),
                to: Node::Hub,
                lane: Some(w),
                dir: Direction::Up,
                sender: w,
                hop: HOP_UPLOAD,
                droppable: true,
                chunk: None,
            });
        }
        for w in 0..k {
            out.push(Transfer {
                from: Node::Hub,
                to: Node::Worker(w),
                lane: Some(w),
                dir: Direction::Down,
                sender: w,
                hop: HOP_UPLOAD,
                droppable: false,
                chunk: None,
            });
        }
        out
    }

    fn mixing_raw(
        &self,
        _round: usize,
        k: usize,
        weights: &[f64],
        landed: &[bool],
    ) -> Vec<Vec<f64>> {
        vec![(0..k)
            .map(|j| if landed[j] { weights[j] } else { 0.0 })
            .collect()]
    }
}

/// Ring all-reduce: reduce-scatter then all-gather, `2(k−1)` hops of
/// `1/k`-sized chunks. Every hop keeps all `k` lanes busy (lane-
/// overlapped), and each hop moves every chunk exactly once, so the
/// billed total is exactly `2(k−1) × Σ_chunks bytes(chunk)` per
/// fragment. The collective is reliable (a dropped chunk would corrupt
/// every replica's sum), so `[comm] drop_prob > 0` is rejected for this
/// topology at config validation.
pub struct Ring;

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn is_decentralized(&self) -> bool {
        true
    }

    fn transfers(&self, _round: usize, k: usize) -> Vec<Transfer> {
        if k <= 1 {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(2 * (k - 1) * k);
        for hop in 0..2 * (k - 1) {
            for w in 0..k {
                out.push(Transfer {
                    from: Node::Worker(w),
                    to: Node::Worker((w + 1) % k),
                    lane: Some(w),
                    dir: Direction::Up,
                    sender: w,
                    hop,
                    droppable: false,
                    chunk: Some(((w + hop) % k, k)),
                });
            }
        }
        out
    }

    fn mixing_raw(
        &self,
        _round: usize,
        k: usize,
        weights: &[f64],
        _landed: &[bool],
    ) -> Vec<Vec<f64>> {
        // Every replica ends the all-reduce holding the same full
        // weighted average — identical rows, identical to star's row.
        (0..k).map(|_| weights.to_vec()).collect()
    }
}

/// NoLoCo-style gossip: each round, a fresh seeded permutation pairs
/// the islands; each pair exchanges outer gradients (two directed,
/// individually droppable sends) and averages. With an odd island
/// count, one island sits the round out (identity mixing row).
pub struct Gossip {
    /// Run seed; the per-round pairing derives from
    /// `Rng::new(seed).child(GOSSIP_STREAM).child(round)`.
    pub seed: u64,
}

/// Child-stream tag separating the gossip pairing from every other
/// consumer of the run seed.
const GOSSIP_STREAM: u64 = 0x676f_7373;

impl Gossip {
    /// The round's disjoint pairs, deterministic in `(seed, round, k)`.
    pub fn pairs(&self, round: usize, k: usize) -> Vec<(usize, usize)> {
        let mut order: Vec<usize> = (0..k).collect();
        Rng::new(self.seed)
            .child(GOSSIP_STREAM)
            .child(round as u64)
            .shuffle(&mut order);
        order.chunks_exact(2).map(|p| (p[0], p[1])).collect()
    }
}

impl Topology for Gossip {
    fn name(&self) -> &'static str {
        "gossip"
    }

    fn is_decentralized(&self) -> bool {
        true
    }

    fn transfers(&self, round: usize, k: usize) -> Vec<Transfer> {
        if k <= 1 {
            return Vec::new();
        }
        let mut out = Vec::new();
        for (a, b) in self.pairs(round, k) {
            for (src, dst) in [(a, b), (b, a)] {
                out.push(Transfer {
                    from: Node::Worker(src),
                    to: Node::Worker(dst),
                    lane: Some(src),
                    dir: Direction::Up,
                    sender: src,
                    hop: HOP_UPLOAD,
                    droppable: true,
                    chunk: None,
                });
            }
        }
        out
    }

    fn mixing_raw(
        &self,
        round: usize,
        k: usize,
        weights: &[f64],
        landed: &[bool],
    ) -> Vec<Vec<f64>> {
        // Identity rows (every island keeps its own gradient), then each
        // delivered pair send opens the partner's entry. A one-sided
        // drop mixes one-sidedly, exactly what the fabric delivered.
        let mut rows: Vec<Vec<f64>> = (0..k)
            .map(|i| {
                let mut row = vec![0.0; k];
                row[i] = weights[i];
                row
            })
            .collect();
        for (a, b) in self.pairs(round, k) {
            if landed[a] {
                rows[b][a] = weights[a];
            }
            if landed[b] {
                rows[a][b] = weights[b];
            }
        }
        rows
    }
}

/// DiLoCoX-style two-level sync: workers aggregate onto a group leader
/// over free intra-group links, leaders exchange with the root over the
/// billed WAN. The root link carries `G` flows instead of `k`; a
/// dropped leader hop (keyed `(round, leader, fragment, hop 1)`)
/// excludes — and desyncs — the whole group for that fragment.
///
/// Like [`Star`], the coordinator's centralized round loop executes
/// this schedule *inline* (it shares the star hot path, which must stay
/// on the golden trace) rather than consuming
/// [`Topology::transfers`]; this declaration is the schedule's
/// specification, and the integration byte-formula tests pin the two in
/// agreement — change them together.
pub struct Hierarchical {
    /// Number of groups `G` (clamped to `[1, k]` per round).
    pub groups: usize,
}

/// Contiguous group partition: group `g` of `G` covers worker range
/// `[g·k/G, (g+1)·k/G)`; the first member is the leader. Empty groups
/// (when `G > k`) are dropped.
pub fn hier_groups(k: usize, groups: usize) -> Vec<Vec<usize>> {
    let g = groups.clamp(1, k.max(1));
    (0..g)
        .map(|i| (i * k / g..(i + 1) * k / g).collect::<Vec<usize>>())
        .filter(|m| !m.is_empty())
        .collect()
}

impl Topology for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn is_decentralized(&self) -> bool {
        false
    }

    fn transfers(&self, _round: usize, k: usize) -> Vec<Transfer> {
        if k <= 1 {
            return Vec::new();
        }
        let mut out = Vec::new();
        let groups = hier_groups(k, self.groups);
        for group in &groups {
            let leader = group[0];
            for &m in &group[1..] {
                out.push(Transfer {
                    from: Node::Worker(m),
                    to: Node::Worker(leader),
                    lane: None, // intra-group: free datacenter link
                    dir: Direction::Up,
                    sender: m,
                    hop: HOP_UPLOAD,
                    droppable: false,
                    chunk: None,
                });
            }
            out.push(Transfer {
                from: Node::Worker(leader),
                to: Node::Hub,
                lane: Some(leader),
                dir: Direction::Up,
                sender: leader,
                hop: HOP_LEADER_UP,
                droppable: true,
                chunk: None,
            });
        }
        for group in &groups {
            let leader = group[0];
            out.push(Transfer {
                from: Node::Hub,
                to: Node::Worker(leader),
                lane: Some(leader),
                dir: Direction::Down,
                sender: leader,
                hop: HOP_LEADER_UP,
                droppable: false,
                chunk: None,
            });
            for &m in &group[1..] {
                out.push(Transfer {
                    from: Node::Worker(leader),
                    to: Node::Worker(m),
                    lane: None,
                    dir: Direction::Down,
                    sender: m,
                    hop: HOP_UPLOAD,
                    droppable: false,
                    chunk: None,
                });
            }
        }
        out
    }

    fn mixing_raw(
        &self,
        _round: usize,
        k: usize,
        weights: &[f64],
        landed: &[bool],
    ) -> Vec<Vec<f64>> {
        // Same single consensus row as star: the two-level weighted
        // average composes exactly (leaders forward weighted partial
        // sums), so the flat worker-order reduction is used verbatim —
        // `landed` is already group-masked by the caller.
        Star.mixing_raw(0, k, weights, landed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::aggregate::WeightedMean;
    use crate::util::prop::check;

    fn all_true(k: usize) -> Vec<bool> {
        vec![true; k]
    }

    #[test]
    fn star_schedule_shape() {
        let ts = Star.transfers(0, 4);
        assert_eq!(ts.len(), 8);
        assert_eq!(ts.iter().filter(|t| t.dir == Direction::Up).count(), 4);
        for t in &ts {
            assert_eq!(t.lane, Some(t.sender));
            assert_eq!(t.hop, HOP_UPLOAD);
            assert_eq!(t.chunk, None);
            assert_eq!(t.droppable, t.dir == Direction::Up);
            if t.dir == Direction::Up {
                assert_eq!(t.to, Node::Hub);
            } else {
                assert_eq!(t.from, Node::Hub);
            }
        }
        assert!(Star.transfers(0, 1).is_empty(), "k=1 is a local outer step");
        assert_eq!(Star.n_replicas(8), 1);
    }

    #[test]
    fn ring_hops_cover_every_chunk_each_hop() {
        for k in [2, 3, 5, 8] {
            let ts = Ring.transfers(0, k);
            assert_eq!(ts.len(), 2 * (k - 1) * k);
            for hop in 0..2 * (k - 1) {
                let mut chunks: Vec<usize> = ts
                    .iter()
                    .filter(|t| t.hop == hop)
                    .map(|t| t.chunk.unwrap().0)
                    .collect();
                chunks.sort_unstable();
                assert_eq!(chunks, (0..k).collect::<Vec<_>>(), "hop {hop} of k={k}");
            }
            // Lane-overlapped: every hop uses every lane exactly once.
            for hop in 0..2 * (k - 1) {
                let mut lanes: Vec<usize> = ts
                    .iter()
                    .filter(|t| t.hop == hop)
                    .map(|t| t.lane.unwrap())
                    .collect();
                lanes.sort_unstable();
                assert_eq!(lanes, (0..k).collect::<Vec<_>>());
            }
            assert!(ts.iter().all(|t| !t.droppable), "ring is reliable");
        }
        assert!(Ring.transfers(0, 1).is_empty());
        assert_eq!(Ring.n_replicas(8), 8);
    }

    #[test]
    fn chunk_elems_tile_exactly() {
        for n in [1usize, 7, 64, 1000] {
            for of in [1usize, 2, 3, 7, 16] {
                let total: usize = (0..of).map(|c| chunk_elems(n, c, of)).sum();
                assert_eq!(total, n, "n={n} of={of}");
            }
        }
    }

    #[test]
    fn gossip_pairs_are_seeded_permutations() {
        let topo = Gossip { seed: 42 };
        for k in [2usize, 5, 8, 9] {
            for round in 0..6 {
                let pairs = topo.pairs(round, k);
                assert_eq!(pairs.len(), k / 2);
                let mut seen: Vec<usize> =
                    pairs.iter().flat_map(|&(a, b)| [a, b]).collect();
                seen.sort_unstable();
                seen.dedup();
                assert_eq!(seen.len(), 2 * (k / 2), "pairs must be disjoint");
                assert!(seen.iter().all(|&w| w < k));
                // Determinism: same (seed, round, k) -> same pairs.
                assert_eq!(pairs, topo.pairs(round, k));
            }
        }
        // Different rounds and different seeds reshuffle.
        let a: Vec<_> = (0..16).map(|r| topo.pairs(r, 8)).collect();
        assert!(a.windows(2).any(|w| w[0] != w[1]), "pairing never varies");
        let other = Gossip { seed: 43 };
        assert!(
            (0..16).any(|r| topo.pairs(r, 8) != other.pairs(r, 8)),
            "pairing ignores the seed"
        );
    }

    #[test]
    fn gossip_mixing_is_row_stochastic_and_pairwise() {
        let topo = Gossip { seed: 3 };
        for k in [2usize, 4, 7] {
            for round in 0..4 {
                let w = vec![1.0; k];
                let m = topo.mixing_matrix(round, k, &w, &all_true(k));
                assert_eq!(m.len(), k);
                let mut paired = 0;
                for (i, row) in m.iter().enumerate() {
                    assert!((row.iter().sum::<f64>() - 1.0).abs() < 1e-12);
                    assert!(row.iter().all(|&x| x >= 0.0));
                    let nonzero = row.iter().filter(|&&x| x > 0.0).count();
                    assert!(nonzero == 1 || nonzero == 2);
                    assert!(row[i] > 0.0, "a replica always keeps itself");
                    if nonzero == 2 {
                        paired += 1;
                        assert!((row[i] - 0.5).abs() < 1e-12, "pairwise mean");
                    }
                }
                assert_eq!(paired, 2 * (k / 2));
            }
        }
    }

    #[test]
    fn gossip_one_sided_drop_mixes_one_sidedly() {
        let topo = Gossip { seed: 0 };
        let k = 4;
        let (a, b) = topo.pairs(0, k)[0];
        // a's outgoing send dropped: b keeps only itself, a still mixes b.
        let mut landed = all_true(k);
        landed[a] = false;
        let m = topo.mixing_matrix(0, k, &vec![1.0; k], &landed);
        assert_eq!(m[b][a], 0.0);
        assert!((m[b][b] - 1.0).abs() < 1e-12);
        assert!((m[a][b] - 0.5).abs() < 1e-12);
        assert!((m[a][a] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn hier_groups_partition_contiguously() {
        for k in [1usize, 2, 5, 8] {
            for g in [1usize, 2, 3, 8, 20] {
                let groups = hier_groups(k, g);
                let flat: Vec<usize> = groups.iter().flatten().copied().collect();
                assert_eq!(flat, (0..k).collect::<Vec<_>>(), "k={k} g={g}");
                assert!(groups.len() <= g.max(1));
                assert!(groups.iter().all(|m| !m.is_empty()));
            }
        }
        assert_eq!(hier_groups(8, 2), vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
    }

    #[test]
    fn hierarchical_bills_only_leader_lanes() {
        let topo = Hierarchical { groups: 2 };
        let ts = topo.transfers(0, 8);
        let wan: Vec<&Transfer> = ts.iter().filter(|t| t.lane.is_some()).collect();
        // 2 leader uploads + 2 root downloads cross the WAN; member hops
        // are free local links.
        assert_eq!(wan.len(), 4);
        for t in &wan {
            assert!(matches!(t.lane, Some(0) | Some(4)), "{t:?}");
            assert_eq!(t.hop, HOP_LEADER_UP);
            assert_eq!(t.droppable, t.dir == Direction::Up);
        }
        assert_eq!(ts.iter().filter(|t| t.lane.is_none()).count(), 2 * 6);
        assert_eq!(topo.n_replicas(8), 1);
    }

    #[test]
    fn prop_ring_average_equals_star_average_bitwise() {
        // The decentralized ring must reproduce star's weighted average
        // bit-for-bit: identical raw mixing rows feed identical scalar
        // operations (normalize, scale first, axpy rest — fixed order).
        check("ring mixing row == star mixing row, bitwise avg", 50, |g| {
            let k = g.usize_in(1..7);
            let len = g.usize_in(1..40);
            let payloads: Vec<Vec<f32>> = (0..k)
                .map(|_| {
                    let mut v = g.f32_vec(len..len + 1, 2.0);
                    v.resize(len, 0.0);
                    v
                })
                .collect();
            let weights: Vec<f64> = (0..k).map(|_| g.f64_in(0.1..5.0)).collect();
            let star_rows = Star.mixing_raw(0, k, &weights, &vec![true; k]);
            let ring_rows = Ring.mixing_raw(0, k, &weights, &vec![true; k]);
            let star_avg = WeightedMean.mean(&payloads, &star_rows[0]);
            let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
            for row in &ring_rows {
                assert_eq!(row, &star_rows[0], "ring rows must equal star's row");
                let ring_avg = WeightedMean.mean(&refs, row);
                for (a, b) in ring_avg.iter().zip(&star_avg) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
                }
            }
        });
    }

    #[test]
    fn mixing_matrices_are_row_stochastic_under_partial_delivery() {
        let topos: Vec<Box<dyn Topology>> = vec![
            Box::new(Star),
            Box::new(Ring),
            Box::new(Gossip { seed: 5 }),
            Box::new(Hierarchical { groups: 2 }),
        ];
        let k = 6;
        let weights: Vec<f64> = (0..k).map(|i| 1.0 + i as f64).collect();
        let landed = vec![true, false, true, true, false, true];
        for topo in &topos {
            let m = topo.mixing_matrix(2, k, &weights, &landed);
            assert_eq!(m.len(), topo.n_replicas(k), "{}", topo.name());
            for row in &m {
                let s: f64 = row.iter().sum();
                assert!(
                    (s - 1.0).abs() < 1e-12 || s == 0.0,
                    "{}: row sums to {s}",
                    topo.name()
                );
                assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)));
            }
        }
    }

    #[test]
    fn star_mixing_masks_dropped_workers() {
        let w = vec![2.0, 3.0, 5.0];
        let rows = Star.mixing_raw(0, 3, &w, &[true, false, true]);
        assert_eq!(rows, vec![vec![2.0, 0.0, 5.0]]);
    }
}
