//! `TcpFabric` — the first real-transport [`Fabric`]: every island is an
//! OS process, reached over loopback/LAN TCP with run-ID rendezvous,
//! heartbeats, and reconnect-as-churn.
//!
//! ## Split of responsibilities (why TCP runs can be bitwise)
//!
//! SimNet never carried payload bytes — it is a billing and drop
//! *oracle* over a modeled link. `TcpFabric` keeps that oracle embedded
//! verbatim: every `try_send_gen`/`send_reliable*`/barrier call
//! delegates to an internal [`SimNet`], so byte bills, drop keys, and
//! `CommStats` rows are backend-independent *by construction*. What the
//! real sockets carry is the **compute plane**: each round the
//! coordinator ships a worker's full island state (params, Adam
//! moments, step, batch-RNG state) to its process, the process runs the
//! H inner steps against its own copy of the AOT artifacts, and ships
//! state + losses back. f32/f64 state round-trips through the frames
//! bit-exactly and PJRT CPU execution is deterministic, so a drop-free
//! loopback run reproduces the simulated trace bitwise — the contract
//! `tests/fabric_equivalence.rs` enforces.
//!
//! The coordinator stays the source of truth for all state, which is
//! what makes the failure model simple: a vanished peer loses nothing
//! (its state lives coordinator-side), so reconnect-as-churn is just
//! roster arithmetic. Mid-phase death books the worker as vanished for
//! the round (losses excluded, sync booked as a drop); a heartbeat
//! failure at round start books a `[churn]`-style leave; a respawned or
//! reconnected process rejoins at the next round's roster with no
//! warm-start machinery needed. See DESIGN.md §14.

use super::fabric::{Fabric, PhaseOutcome};
use super::{frame, CommStats, Direction, SimNet};
use crate::checkpoint::{w_f64, w_tensors, w_u32, w_u64, Reader};
use crate::data::batch::BatchIter;
use crate::engine::InnerPhaseReport;
use crate::runtime::{Runtime, Tensors};
use crate::util::rng::Rng;
use crate::worker::Worker;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Everything `TcpFabric` needs, as plain fields so `comm` stays
/// independent of the `config` layer (the coordinator assembles this
/// from `[fabric]` + the manifest + the dataset).
pub struct TcpFabricSetup {
    /// The embedded billing/drop oracle — same construction as the pure
    /// sim path (same seed lineage), which is what keeps bills bitwise.
    pub sim: SimNet,
    /// Worker-slot count (the experiment's max roster size).
    pub pool: usize,
    /// Interface to bind; workers connect here.
    pub host: String,
    /// Listen port; 0 picks an ephemeral port (see
    /// [`TcpFabric::local_port`]).
    pub port: u16,
    /// Rendezvous token: a HELLO carrying anything else is rejected.
    pub run_id: String,
    /// Spawn one worker process per slot (and respawn dead ones). Off
    /// for externally-launched workers.
    pub spawn: bool,
    /// Binary to spawn (`<bin> worker --port .. --run-id ..`).
    pub worker_bin: Option<String>,
    /// Extra per-slot argv for spawned workers (fault injection hooks).
    pub spawn_extra: Vec<Vec<String>>,
    /// AOT artifact dir + model preset the workers load.
    pub artifacts_dir: String,
    pub model: String,
    /// Per-slot token streams, shipped at INIT.
    pub shards: Vec<Vec<i32>>,
    pub batch_size: usize,
    pub seq_len: usize,
    /// Manifest leaf shape products — bounds every state decode.
    pub leaf_sizes: Vec<usize>,
    /// Rendezvous / reconnect budget.
    pub connect_timeout_s: f64,
    /// Bound on one RUN_PHASE round-trip (a hung peer becomes a drop,
    /// not a hang).
    pub phase_timeout_s: f64,
    /// Bound on one PING/PONG round-trip.
    pub heartbeat_timeout_s: f64,
}

struct Peer {
    stream: Option<TcpStream>,
    child: Option<Child>,
}

/// Multi-process TCP backend. Billing delegates to the embedded
/// [`SimNet`]; sockets carry island state and losses.
pub struct TcpFabric {
    sim: SimNet,
    listener: Option<TcpListener>,
    host: String,
    port: u16,
    peers: Vec<Peer>,
    phase_seq: u64,
    run_id: String,
    spawn: bool,
    worker_bin: Option<String>,
    spawn_extra: Vec<Vec<String>>,
    artifacts_dir: String,
    model: String,
    shards: Vec<Vec<i32>>,
    batch_size: usize,
    seq_len: usize,
    leaf_sizes: Vec<usize>,
    connect_timeout_s: f64,
    phase_timeout_s: f64,
    heartbeat_timeout_s: f64,
}

/// Body bytes of one serialized tensor tree (`w_tensors` layout).
fn tensors_wire_bytes(leaf_sizes: &[usize]) -> usize {
    4 + leaf_sizes.iter().map(|&n| 8 + 4 * n).sum::<usize>()
}

/// Frame-body cap for RUN_PHASE / PHASE_DONE: three tensor trees plus
/// scalars and the loss vector.
fn state_body_cap(leaf_sizes: &[usize], h: usize) -> usize {
    3 * tensors_wire_bytes(leaf_sizes) + 4 * h + 128
}

fn decode_raw_tensors(
    r: &mut Reader<'_>,
    leaf_sizes: &[usize],
    what: &str,
) -> Result<Vec<Vec<f32>>> {
    let n = r.u32()? as usize;
    ensure!(
        n == leaf_sizes.len(),
        "{what}: frame has {n} leaves, manifest wants {}",
        leaf_sizes.len()
    );
    leaf_sizes
        .iter()
        .map(|&want| r.f32_leaf(want, what))
        .collect()
}

/// Island state as it crosses the wire: step + batch-RNG + the three
/// tensor trees, all bit-exact (f32/f64 LE, u64 LE).
fn encode_state(body: &mut Vec<u8>, w: &Worker) {
    w_f64(body, w.step);
    for s in w.iter.rng_state() {
        w_u64(body, s);
    }
    w_tensors(body, &w.params);
    w_tensors(body, &w.opt_m);
    w_tensors(body, &w.opt_v);
}

struct WireState {
    step: f64,
    rng: [u64; 4],
    params: Vec<Vec<f32>>,
    opt_m: Vec<Vec<f32>>,
    opt_v: Vec<Vec<f32>>,
}

fn decode_state(r: &mut Reader<'_>, leaf_sizes: &[usize]) -> Result<WireState> {
    let step = r.f64()?;
    let rng = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    let params = decode_raw_tensors(r, leaf_sizes, "params")?;
    let opt_m = decode_raw_tensors(r, leaf_sizes, "opt_m")?;
    let opt_v = decode_raw_tensors(r, leaf_sizes, "opt_v")?;
    Ok(WireState { step, rng, params, opt_m, opt_v })
}

fn apply_state(w: &mut Worker, s: WireState) {
    w.step = s.step;
    w.iter.set_rng_state(s.rng);
    w.params = Tensors::from_raw(s.params);
    w.opt_m = Tensors::from_raw(s.opt_m);
    w.opt_v = Tensors::from_raw(s.opt_v);
}

struct PhaseReply {
    compute_s: f64,
    losses: Vec<f32>,
    state: WireState,
}

fn decode_phase_done(body: &[u8], leaf_sizes: &[usize], seq: u64, h: usize) -> Result<PhaseReply> {
    let mut r = Reader::new(body, 0);
    let got_seq = r.u64()?;
    ensure!(got_seq == seq, "stale PHASE_DONE (seq {got_seq}, want {seq})");
    let compute_s = r.f64()?;
    let n = r.len_capped(h, "losses")?;
    ensure!(n == h, "PHASE_DONE carries {n} losses, want {h}");
    let raw = r.take(4 * n)?;
    let losses = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let state = decode_state(&mut r, leaf_sizes)?;
    r.finish()?;
    Ok(PhaseReply { compute_s, losses, state })
}

impl TcpFabric {
    /// Bind the listener and (when configured) spawn the worker pool,
    /// without waiting for connections — call [`Self::rendezvous`] next.
    /// Split from [`Self::new`] so externally-launched peers can learn
    /// the ephemeral port before the accept loop starts.
    pub fn bind(setup: TcpFabricSetup) -> Result<TcpFabric> {
        ensure!(
            setup.shards.len() >= setup.pool,
            "need one data shard per worker slot ({} < {})",
            setup.shards.len(),
            setup.pool
        );
        for t in [
            setup.connect_timeout_s,
            setup.phase_timeout_s,
            setup.heartbeat_timeout_s,
        ] {
            ensure!(t > 0.0, "fabric timeouts must be positive (got {t})");
        }
        let mut fab = TcpFabric {
            sim: setup.sim,
            listener: None,
            host: setup.host,
            port: setup.port,
            peers: (0..setup.pool)
                .map(|_| Peer { stream: None, child: None })
                .collect(),
            phase_seq: 0,
            run_id: setup.run_id,
            spawn: setup.spawn,
            worker_bin: setup.worker_bin,
            spawn_extra: setup.spawn_extra,
            artifacts_dir: setup.artifacts_dir,
            model: setup.model,
            shards: setup.shards,
            batch_size: setup.batch_size,
            seq_len: setup.seq_len,
            leaf_sizes: setup.leaf_sizes,
            connect_timeout_s: setup.connect_timeout_s,
            phase_timeout_s: setup.phase_timeout_s,
            heartbeat_timeout_s: setup.heartbeat_timeout_s,
        };
        if fab.peers.is_empty() {
            return Ok(fab); // billing-only instance: no sockets at all
        }
        let listener = TcpListener::bind((fab.host.as_str(), fab.port))
            .with_context(|| format!("binding fabric listener on {}:{}", fab.host, fab.port))?;
        listener.set_nonblocking(true)?;
        fab.port = listener.local_addr()?.port();
        fab.listener = Some(listener);
        if fab.spawn {
            for i in 0..fab.peers.len() {
                fab.spawn_child(i)?;
            }
        }
        Ok(fab)
    }

    /// Bind + block until the whole pool has completed rendezvous.
    pub fn new(setup: TcpFabricSetup) -> Result<TcpFabric> {
        let mut fab = TcpFabric::bind(setup)?;
        fab.rendezvous()?;
        Ok(fab)
    }

    /// The bound listen port (resolves port 0 to the ephemeral choice).
    pub fn local_port(&self) -> u16 {
        self.port
    }

    /// Wait (bounded by `connect_timeout_s`) until every slot has a
    /// connected, rendezvoused peer.
    pub fn rendezvous(&mut self) -> Result<()> {
        let deadline = Instant::now() + Duration::from_secs_f64(self.connect_timeout_s);
        while self.peers.iter().any(|p| p.stream.is_none()) {
            self.accept_pending()?;
            if self.peers.iter().all(|p| p.stream.is_some()) {
                break;
            }
            // A spawned child that died before connecting will never
            // show up — fail fast with its exit status.
            for (i, p) in self.peers.iter_mut().enumerate() {
                if p.stream.is_none() {
                    if let Some(child) = p.child.as_mut() {
                        if let Some(status) = child.try_wait()? {
                            bail!("worker process for slot {i} exited during rendezvous: {status}");
                        }
                    }
                }
            }
            if Instant::now() >= deadline {
                let missing: Vec<usize> = self
                    .peers
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| p.stream.is_none())
                    .map(|(i, _)| i)
                    .collect();
                bail!(
                    "fabric rendezvous timed out after {}s; slots without a worker: {missing:?}",
                    self.connect_timeout_s
                );
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        Ok(())
    }

    fn spawn_child(&mut self, slot: usize) -> Result<()> {
        let bin = self
            .worker_bin
            .as_ref()
            .ok_or_else(|| anyhow!("fabric.spawn is on but no worker binary is configured"))?;
        let mut cmd = Command::new(bin);
        cmd.arg("worker")
            .arg("--host")
            .arg(&self.host)
            .arg("--port")
            .arg(self.port.to_string())
            .arg("--run-id")
            .arg(&self.run_id)
            .arg("--artifacts")
            .arg(&self.artifacts_dir)
            .arg("--model")
            .arg(&self.model)
            .arg("--connect-timeout-s")
            .arg(self.connect_timeout_s.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit());
        if let Some(extra) = self.spawn_extra.get(slot) {
            cmd.args(extra);
        }
        let child = cmd
            .spawn()
            .with_context(|| format!("spawning worker process {bin:?} for slot {slot}"))?;
        self.peers[slot].child = Some(child);
        Ok(())
    }

    /// Drain the accept queue, running rendezvous on each connection and
    /// assigning the lowest empty slot. Non-blocking.
    fn accept_pending(&mut self) -> Result<()> {
        loop {
            let accepted = match self.listener.as_ref() {
                None => return Ok(()),
                Some(listener) => listener.accept(),
            };
            match accepted {
                Ok((stream, addr)) => {
                    if let Err(e) = self.handshake(stream) {
                        eprintln!("[fabric] rejected connection from {addr}: {e}");
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) => return Err(e).context("fabric accept"),
            }
        }
    }

    /// HELLO (validate run ID) → HELLO_ACK (slot) → INIT (shard).
    fn handshake(&mut self, mut stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs_f64(self.connect_timeout_s)))?;
        stream.set_write_timeout(Some(Duration::from_secs_f64(self.connect_timeout_s)))?;
        let (t, body) = frame::read_frame(&mut stream, 256)?;
        ensure!(t == frame::HELLO, "expected HELLO, got frame type {t}");
        let mut r = Reader::new(&body, 0);
        let n = r.len_capped(200, "run-id length")?;
        let got = std::str::from_utf8(r.take(n)?).context("run-id utf8")?;
        r.finish()?;
        ensure!(
            got == self.run_id,
            "run-ID mismatch: peer says {got:?}, this run is {:?}",
            self.run_id
        );
        let slot = self
            .peers
            .iter()
            .position(|p| p.stream.is_none())
            .ok_or_else(|| anyhow!("no free worker slot"))?;
        let mut ack = Vec::new();
        w_u32(&mut ack, slot as u32);
        frame::write_frame(&mut stream, frame::HELLO_ACK, &ack)?;
        let shard = &self.shards[slot];
        let mut init = Vec::with_capacity(12 + 4 * shard.len());
        w_u32(&mut init, self.batch_size as u32);
        w_u32(&mut init, self.seq_len as u32);
        w_u64(&mut init, shard.len() as u64);
        for &tok in shard {
            init.extend_from_slice(&tok.to_le_bytes());
        }
        frame::write_frame(&mut stream, frame::INIT, &init)?;
        self.peers[slot].stream = Some(stream);
        Ok(())
    }

    /// Synchronous heartbeat; a failure drops the connection.
    fn ping(&mut self, id: usize) -> bool {
        let hb = Duration::from_secs_f64(self.heartbeat_timeout_s);
        let Some(stream) = self.peers[id].stream.as_mut() else { return false };
        let ok = (|| -> Result<()> {
            stream.set_read_timeout(Some(hb))?;
            stream.set_write_timeout(Some(hb))?;
            frame::write_frame(stream, frame::PING, &[])?;
            let (t, _) = frame::read_frame(stream, 16)?;
            ensure!(t == frame::PONG, "expected PONG, got frame type {t}");
            Ok(())
        })();
        if ok.is_err() {
            self.peers[id].stream = None;
        }
        ok.is_ok()
    }
}

impl Drop for TcpFabric {
    fn drop(&mut self) {
        for p in &mut self.peers {
            if let Some(stream) = p.stream.as_mut() {
                let _ = frame::write_frame(stream, frame::SHUTDOWN, &[]);
            }
            if let Some(mut child) = p.child.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

impl Fabric for TcpFabric {
    // ---- billing plane: pure delegation to the embedded oracle ----

    fn try_send_gen(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
        fragment: usize,
        hop: usize,
        gen: usize,
    ) -> bool {
        self.sim
            .try_send_gen(bytes, dir, round, worker, fragment, hop, gen)
    }

    fn send_reliable(&mut self, bytes: u64, dir: Direction) {
        self.sim.send_reliable(bytes, dir)
    }

    fn send_reliable_to(&mut self, bytes: u64, dir: Direction, worker: usize) {
        self.sim.send_reliable_to(bytes, dir, worker)
    }

    fn end_round(&mut self) {
        self.sim.end_round()
    }

    fn end_round_deferred(&mut self) -> f64 {
        self.sim.end_round_deferred()
    }

    fn stats(&self) -> &CommStats {
        self.sim.stats()
    }

    fn transfer_time(&self, bytes: u64) -> f64 {
        self.sim.transfer_time(bytes)
    }

    // ---- compute plane: real processes ----

    /// Round-start maintenance: drain reconnects, heartbeat the roster
    /// (a dead peer is booked as a `[churn]` leave for this round), and
    /// respawn dead slots so the replacement rejoins next round.
    fn filter_roster(&mut self, round: usize, roster: Vec<usize>) -> Result<Vec<usize>> {
        self.accept_pending()?;
        let mut alive = Vec::with_capacity(roster.len());
        for &id in &roster {
            if self.ping(id) {
                alive.push(id);
            } else {
                eprintln!("[churn] worker {id} left at round {round} (fabric heartbeat)");
            }
        }
        if self.spawn {
            for i in 0..self.peers.len() {
                if self.peers[i].stream.is_none() {
                    // Kill a lingering (hung or half-dead) process before
                    // replacing it; the respawn reconnects and rejoins at
                    // the next round's accept drain.
                    if let Some(mut child) = self.peers[i].child.take() {
                        let _ = child.kill();
                        let _ = child.wait();
                    }
                    self.spawn_child(i)?;
                }
            }
        }
        ensure!(
            !alive.is_empty(),
            "round {round}: no reachable TCP worker in roster {roster:?}"
        );
        Ok(alive)
    }

    /// Ship state to every roster member, run the phase remotely, and
    /// collect state + losses. A peer that fails the exchange (EOF,
    /// timeout, malformed reply) is marked vanished: its coordinator-side
    /// state is untouched and its connection dropped.
    fn run_phase(
        &mut self,
        workers: &mut [Worker],
        ids: &[usize],
        h: usize,
    ) -> Result<Option<PhaseOutcome>> {
        self.phase_seq += 1;
        let seq = self.phase_seq;
        let cap = state_body_cap(&self.leaf_sizes, h);
        let timeout = Duration::from_secs_f64(self.phase_timeout_s);

        let requests: Vec<Vec<u8>> = ids
            .iter()
            .map(|&id| {
                let mut body = Vec::with_capacity(cap);
                w_u64(&mut body, seq);
                w_u64(&mut body, h as u64);
                encode_state(&mut body, &workers[id]);
                frame::encode(frame::RUN_PHASE, &body)
            })
            .collect();
        let mut taken: Vec<Option<TcpStream>> =
            ids.iter().map(|&id| self.peers[id].stream.take()).collect();

        fn exchange(
            stream: Option<TcpStream>,
            request: &[u8],
            timeout: Duration,
            cap: usize,
        ) -> Result<(TcpStream, Vec<u8>, f64)> {
            let mut stream = stream.ok_or_else(|| anyhow!("peer not connected"))?;
            let t0 = Instant::now();
            stream.set_read_timeout(Some(timeout))?;
            stream.set_write_timeout(Some(timeout))?;
            std::io::Write::write_all(&mut stream, request).context("phase request write")?;
            let (t, body) = frame::read_frame(&mut stream, cap)?;
            ensure!(t == frame::PHASE_DONE, "expected PHASE_DONE, got frame type {t}");
            Ok((stream, body, t0.elapsed().as_secs_f64()))
        }

        let results: Vec<Result<(TcpStream, Vec<u8>, f64)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = taken
                .drain(..)
                .zip(&requests)
                .map(|(stream, request)| {
                    scope.spawn(move || exchange(stream, request, timeout, cap))
                })
                .collect();
            handles
                .into_iter()
                .map(|handle| {
                    handle
                        .join()
                        .unwrap_or_else(|_| Err(anyhow!("phase exchange thread panicked")))
                })
                .collect()
        });

        let mut vanished = vec![false; ids.len()];
        let mut losses = Vec::with_capacity(ids.len());
        let mut compute_s = vec![0.0; ids.len()];
        let mut wall_s = vec![0.0; ids.len()];
        for (pos, res) in results.into_iter().enumerate() {
            let id = ids[pos];
            let applied = res.and_then(|(stream, body, wall)| {
                let reply = decode_phase_done(&body, &self.leaf_sizes, seq, h)?;
                Ok((stream, reply, wall))
            });
            match applied {
                Ok((stream, reply, wall)) => {
                    apply_state(&mut workers[id], reply.state);
                    workers[id].compute_seconds += reply.compute_s;
                    compute_s[pos] = reply.compute_s;
                    wall_s[pos] = wall;
                    losses.push(reply.losses);
                    self.peers[id].stream = Some(stream);
                }
                Err(e) => {
                    vanished[pos] = true;
                    losses.push(vec![0.0; h]);
                    eprintln!("[churn] worker {id} vanished mid-phase ({e})");
                }
            }
        }
        ensure!(
            vanished.iter().any(|&v| !v),
            "every TCP worker vanished during the inner phase"
        );
        Ok(Some(PhaseOutcome {
            report: InnerPhaseReport::from_parts(losses, compute_s, wall_s),
            vanished,
        }))
    }
}

// ---- worker-process side ------------------------------------------------

/// Options for [`serve_worker`] (the `diloco worker` subcommand).
pub struct WorkerOpts {
    pub host: String,
    pub port: u16,
    pub run_id: String,
    pub artifacts_dir: String,
    pub model: String,
    pub connect_timeout_s: f64,
    /// Fault injection (tests): exit cleanly after replying to this many
    /// phases…
    pub die_after_phases: Option<u64>,
    /// …or exit without replying on the Nth (0-based) RUN_PHASE…
    pub die_mid_phase: Option<u64>,
    /// …or hang forever on the Nth RUN_PHASE (exercises the phase
    /// timeout).
    pub hang_mid_phase: Option<u64>,
}

fn connect_with_backoff(host: &str, port: u16, timeout_s: f64) -> Result<TcpStream> {
    let deadline = Instant::now() + Duration::from_secs_f64(timeout_s);
    let mut delay = Duration::from_millis(50);
    loop {
        match TcpStream::connect((host, port)) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                ensure!(
                    Instant::now() + delay < deadline,
                    "connecting to {host}:{port} timed out after {timeout_s}s: {e}"
                );
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_secs(1));
            }
        }
    }
}

/// Run one island as a server-less worker process: connect (with
/// backoff), rendezvous by run ID, then serve PING and RUN_PHASE frames
/// until SHUTDOWN or disconnect. All training state arrives with each
/// phase, so a worker process is stateless across phases — the property
/// that makes coordinator-side churn/resume semantics exact.
pub fn serve_worker(opts: WorkerOpts) -> Result<()> {
    let rt = Runtime::load(&opts.artifacts_dir, &opts.model)?;
    let leaf_sizes: Vec<usize> =
        rt.manifest.params.iter().map(|s| s.elements()).collect();
    let mut stream = connect_with_backoff(&opts.host, opts.port, opts.connect_timeout_s)?;
    stream.set_nodelay(true)?;

    let mut hello = Vec::new();
    w_u64(&mut hello, opts.run_id.len() as u64);
    hello.extend_from_slice(opts.run_id.as_bytes());
    frame::write_frame(&mut stream, frame::HELLO, &hello)?;
    let (t, body) = frame::read_frame(&mut stream, 16)?;
    ensure!(t == frame::HELLO_ACK, "rendezvous rejected (frame type {t})");
    let mut r = Reader::new(&body, 0);
    let slot = r.u32()? as usize;
    r.finish()?;

    let (t, body) = frame::read_frame(&mut stream, frame::MAX_FRAME_BODY)?;
    ensure!(t == frame::INIT, "expected INIT, got frame type {t}");
    let mut r = Reader::new(&body, 0);
    let batch_size = r.u32()? as usize;
    let seq_len = r.u32()? as usize;
    let n_tokens = r.len_capped(frame::MAX_FRAME_BODY / 4, "shard tokens")?;
    let tokens: Vec<i32> = r
        .take(4 * n_tokens)?
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    r.finish()?;

    // The batch RNG is overwritten by every RUN_PHASE, so the seed here
    // is irrelevant — the coordinator's shipped state is authoritative.
    let zeros = Tensors::zeros(&rt.manifest);
    let iter = BatchIter::new(tokens, batch_size, seq_len, Rng::new(0));
    let mut worker = Worker::new(slot, zeros.clone(), zeros, iter);

    let mut phases_done = 0u64;
    loop {
        let cap = state_body_cap(&leaf_sizes, 0);
        let (t, body) = frame::read_frame(&mut stream, cap)?;
        match t {
            frame::PING => frame::write_frame(&mut stream, frame::PONG, &[])?,
            frame::SHUTDOWN => return Ok(()),
            frame::RUN_PHASE => {
                if opts.die_mid_phase == Some(phases_done) {
                    std::process::exit(0); // vanish without a reply
                }
                if opts.hang_mid_phase == Some(phases_done) {
                    loop {
                        std::thread::sleep(Duration::from_secs(3600));
                    }
                }
                let mut r = Reader::new(&body, 0);
                let seq = r.u64()?;
                let h = r.len_capped(1 << 24, "inner steps")?;
                let state = decode_state(&mut r, &leaf_sizes)?;
                r.finish()?;
                apply_state(&mut worker, state);
                let compute_0 = worker.compute_seconds;
                let mut losses = Vec::with_capacity(h);
                worker.run_inner_steps(&rt, h, &mut losses)?;

                let mut reply = Vec::with_capacity(cap);
                w_u64(&mut reply, seq);
                w_f64(&mut reply, worker.compute_seconds - compute_0);
                w_u64(&mut reply, losses.len() as u64);
                for &l in &losses {
                    reply.extend_from_slice(&l.to_le_bytes());
                }
                encode_state(&mut reply, &worker);
                frame::write_frame(&mut stream, frame::PHASE_DONE, &reply)?;
                phases_done += 1;
                if opts.die_after_phases == Some(phases_done) {
                    return Ok(()); // clean exit after the reply
                }
            }
            other => bail!("unexpected frame type {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::Codec;
    use crate::comm::wire;
    use crate::util::prop;
    use std::io::Write;
    use std::thread;

    fn billing_only(sim: SimNet) -> TcpFabric {
        TcpFabric::new(TcpFabricSetup {
            sim,
            pool: 0,
            host: "127.0.0.1".into(),
            port: 0,
            run_id: "prop".into(),
            spawn: false,
            worker_bin: None,
            spawn_extra: Vec::new(),
            artifacts_dir: String::new(),
            model: String::new(),
            shards: Vec::new(),
            batch_size: 1,
            seq_len: 1,
            leaf_sizes: Vec::new(),
            connect_timeout_s: 1.0,
            phase_timeout_s: 1.0,
            heartbeat_timeout_s: 1.0,
        })
        .unwrap()
    }

    /// Satellite: `Fabric` billing is backend-independent. For any
    /// sampled (topology hops, fragments, codec, prune density) traffic
    /// pattern, the SimNet backend and the TCP backend report identical
    /// `CommStats` — totals *and* per-round rows — because TCP embeds the
    /// same oracle rather than re-deriving bills from socket traffic.
    #[test]
    fn billing_is_backend_independent_for_any_traffic_pattern() {
        prop::check("fabric_billing_backend_independent", 64, |g| {
            let bandwidth = g.f64_in(1e3..1e9);
            let latency = g.f64_in(0.0..0.05);
            let drop_prob = g.f64_in(0.0..1.0);
            let seed = g.rng().next_u64();
            let rounds = g.usize_in(1..4);
            let workers = g.usize_in(1..5);
            let fragments = g.usize_in(1..4);
            let codec =
                [Codec::F32, Codec::F16, Codec::Q8, Codec::Q4, Codec::Q2][g.usize_in(0..5)];
            let n_elements = g.usize_in(1..5000);

            // One sampled traffic plan, replayed against both backends:
            // droppable keyed sends with sparse-wire bills, plus
            // reliable lane traffic, plus a barrier fold per round.
            let mut plan = Vec::new();
            for round in 0..rounds {
                for w in 0..workers {
                    for f in 0..fragments {
                        let nnz = g.usize_in(0..n_elements + 1);
                        let bytes = wire::sparse_payload_bytes(codec, n_elements, nnz, 1);
                        let hop = g.usize_in(0..3);
                        let gen = g.usize_in(0..3);
                        plan.push((round, w, f, hop, gen, bytes, g.bool()));
                    }
                }
            }
            let deferred: Vec<bool> = (0..rounds).map(|_| g.bool()).collect();

            let drive = |fab: &mut dyn Fabric| {
                for &(round, w, f, hop, gen, bytes, reliable_too) in &plan {
                    fab.try_send_gen(bytes, Direction::Up, round, w, f, hop, gen);
                    if reliable_too {
                        fab.send_reliable_to(bytes, Direction::Down, w);
                    }
                    if round + w == 0 {
                        fab.send_reliable(bytes / 2 + 1, Direction::Up);
                    }
                }
                let mut deferred_total = 0.0;
                for &d in &deferred {
                    if d {
                        deferred_total += fab.end_round_deferred();
                    } else {
                        fab.end_round();
                    }
                }
                deferred_total
            };

            let mut sim: Box<dyn Fabric> =
                Box::new(SimNet::new(bandwidth, latency, drop_prob, Rng::new(seed)));
            let mut tcp: Box<dyn Fabric> = Box::new(billing_only(SimNet::new(
                bandwidth,
                latency,
                drop_prob,
                Rng::new(seed),
            )));
            let a = drive(sim.as_mut());
            let b = drive(tcp.as_mut());
            assert_eq!(a.to_bits(), b.to_bits(), "deferred barrier diverged");
            assert_eq!(sim.stats(), tcp.stats(), "CommStats diverged");
        });
    }

    // ---- protocol tests against hand-rolled fake peers (no artifacts,
    // no Runtime: these exercise rendezvous, heartbeats, the phase
    // exchange, and vanish booking at the fabric level) ----

    const LEAVES: [usize; 2] = [3, 2];

    fn tiny_tensors(fill: f32) -> Tensors {
        Tensors::from_raw(vec![vec![fill; LEAVES[0]], vec![fill; LEAVES[1]]])
    }

    fn tiny_worker(id: usize) -> Worker {
        let iter = BatchIter::new(vec![1; 64], 1, 4, Rng::new(9));
        Worker::new(id, tiny_tensors(id as f32), tiny_tensors(0.0), iter)
    }

    fn test_setup(pool: usize) -> TcpFabricSetup {
        TcpFabricSetup {
            sim: SimNet::new(1e6, 0.0, 0.0, Rng::new(1)),
            pool,
            host: "127.0.0.1".into(),
            port: 0,
            run_id: "nano-test".into(),
            spawn: false,
            worker_bin: None,
            spawn_extra: Vec::new(),
            artifacts_dir: String::new(),
            model: String::new(),
            shards: vec![vec![0; 32]; pool],
            batch_size: 1,
            seq_len: 4,
            leaf_sizes: LEAVES.to_vec(),
            connect_timeout_s: 10.0,
            phase_timeout_s: 2.0,
            heartbeat_timeout_s: 1.0,
        }
    }

    /// A protocol-complete fake worker: rendezvous, PONG heartbeats, and
    /// on RUN_PHASE either echo the state back perturbed (`+1.0` on
    /// every element, `step + 1`, losses = slot+1) or — when its
    /// assigned slot equals `die_slot` — vanish without replying.
    fn fake_worker(port: u16, run_id: &str, die_slot: Option<usize>) -> thread::JoinHandle<()> {
        let run_id = run_id.to_string();
        thread::spawn(move || {
            let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut hello = Vec::new();
            w_u64(&mut hello, run_id.len() as u64);
            hello.extend_from_slice(run_id.as_bytes());
            frame::write_frame(&mut stream, frame::HELLO, &hello).unwrap();
            let (t, body) = frame::read_frame(&mut stream, 16).unwrap();
            assert_eq!(t, frame::HELLO_ACK);
            let slot = Reader::new(&body, 0).u32().unwrap() as usize;
            let (t, _) = frame::read_frame(&mut stream, frame::MAX_FRAME_BODY).unwrap();
            assert_eq!(t, frame::INIT);
            loop {
                let Ok((t, body)) = frame::read_frame(&mut stream, 1 << 20) else { return };
                match t {
                    frame::PING => {
                        frame::write_frame(&mut stream, frame::PONG, &[]).unwrap()
                    }
                    frame::SHUTDOWN => return,
                    frame::RUN_PHASE => {
                        if die_slot == Some(slot) {
                            return; // drop the socket mid-phase
                        }
                        let mut r = Reader::new(&body, 0);
                        let seq = r.u64().unwrap();
                        let h = r.u64().unwrap() as usize;
                        let mut state = decode_state(&mut r, &LEAVES).unwrap();
                        state.step += 1.0;
                        for leaf in state.params.iter_mut() {
                            for x in leaf.iter_mut() {
                                *x += 1.0;
                            }
                        }
                        let mut reply = Vec::new();
                        w_u64(&mut reply, seq);
                        w_f64(&mut reply, 0.25);
                        w_u64(&mut reply, h as u64);
                        for _ in 0..h {
                            reply.extend_from_slice(
                                &((slot + 1) as f32).to_le_bytes(),
                            );
                        }
                        w_f64(&mut reply, state.step);
                        for s in state.rng {
                            w_u64(&mut reply, s);
                        }
                        for leaves in [&state.params, &state.opt_m, &state.opt_v] {
                            w_u32(&mut reply, leaves.len() as u32);
                            for leaf in leaves.iter() {
                                w_u64(&mut reply, leaf.len() as u64);
                                for x in leaf.iter() {
                                    reply.extend_from_slice(&x.to_le_bytes());
                                }
                            }
                        }
                        frame::write_frame(&mut stream, frame::PHASE_DONE, &reply).unwrap();
                    }
                    other => panic!("fake worker got frame type {other}"),
                }
            }
        })
    }

    #[test]
    fn rendezvous_assigns_slots_and_rejects_wrong_run_id() {
        let mut fab = TcpFabric::bind(test_setup(2)).unwrap();
        let port = fab.local_port();
        // An impostor with the wrong run ID must be rejected without
        // consuming a slot; two legitimate peers then fill the pool.
        let impostor = thread::spawn(move || {
            let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut hello = Vec::new();
            w_u64(&mut hello, 5);
            hello.extend_from_slice(b"wrong");
            frame::write_frame(&mut stream, frame::HELLO, &hello).unwrap();
            // The coordinator drops us: expect EOF, not a HELLO_ACK.
            assert!(frame::read_frame(&mut stream, 16).is_err());
        });
        let a = fake_worker(port, "nano-test", None);
        let b = fake_worker(port, "nano-test", None);
        fab.rendezvous().unwrap();
        let roster = fab.filter_roster(0, vec![0, 1]).unwrap();
        assert_eq!(roster, vec![0, 1]);
        drop(fab); // SHUTDOWN → fake workers exit
        impostor.join().unwrap();
        a.join().unwrap();
        b.join().unwrap();
    }

    #[test]
    fn phase_roundtrip_updates_state_and_books_mid_phase_death_as_vanish() {
        let mut fab = TcpFabric::bind(test_setup(2)).unwrap();
        let port = fab.local_port();
        // Slot 1 dies on its first RUN_PHASE; slot 0 echoes perturbed
        // state. Slot assignment is arrival-order, so both fakes carry
        // the same behavior switch and consult their assigned slot.
        let a = fake_worker(port, "nano-test", Some(1));
        let b = fake_worker(port, "nano-test", Some(1));
        fab.rendezvous().unwrap();

        let mut workers = vec![tiny_worker(0), tiny_worker(1)];
        let step_before = [workers[0].step, workers[1].step];
        let out = fab
            .run_phase(&mut workers, &[0, 1], 3)
            .unwrap()
            .expect("tcp backend always owns the phase");
        assert_eq!(out.vanished, vec![false, true]);
        // Live worker: state advanced exactly as the peer replied.
        assert_eq!(workers[0].step, step_before[0] + 1.0);
        assert_eq!(workers[0].params.leaves()[0], vec![1.0; 3]);
        assert_eq!(out.report.per_worker_losses[0], vec![1.0; 3]);
        // Vanished worker: coordinator-side state untouched, zero-filled
        // loss row (excluded from the fold by the vanished flag).
        assert_eq!(workers[1].step, step_before[1]);
        assert_eq!(workers[1].params.leaves()[0], vec![1.0; 3]);
        assert_eq!(out.report.per_worker_losses[1], vec![0.0; 3]);

        // The dead peer is then booked as a churn leave at round start.
        let roster = fab.filter_roster(1, vec![0, 1]).unwrap();
        assert_eq!(roster, vec![0]);

        drop(fab);
        a.join().unwrap();
        b.join().unwrap();
    }

    #[test]
    fn hung_peer_is_bounded_by_the_phase_timeout() {
        let mut setup = test_setup(1);
        setup.phase_timeout_s = 0.3;
        let mut fab = TcpFabric::bind(setup).unwrap();
        let port = fab.local_port();
        // A peer that rendezvouses and then goes silent on RUN_PHASE.
        let peer = thread::spawn(move || {
            let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut hello = Vec::new();
            w_u64(&mut hello, 9);
            hello.extend_from_slice(b"nano-test");
            frame::write_frame(&mut stream, frame::HELLO, &hello).unwrap();
            frame::read_frame(&mut stream, 16).unwrap();
            frame::read_frame(&mut stream, frame::MAX_FRAME_BODY).unwrap();
            // Swallow the RUN_PHASE and never answer; exit when the
            // coordinator gives up and closes.
            let mut buf = [0u8; 4096];
            while let Ok(n) = std::io::Read::read(&mut stream, &mut buf) {
                if n == 0 {
                    return;
                }
            }
        });
        fab.rendezvous().unwrap();
        let mut workers = vec![tiny_worker(0), tiny_worker(1)];
        let t0 = Instant::now();
        // The only roster member hangs → the phase errors out (bounded),
        // rather than reporting a fully-vanished round or blocking.
        let err = fab.run_phase(&mut workers, &[0], 2).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(10), "timeout did not bound the stall");
        assert!(err.to_string().contains("vanished"), "{err}");
        drop(fab);
        peer.join().unwrap();
    }

    #[test]
    fn stale_or_corrupt_phase_reply_is_a_vanish_not_a_panic() {
        let mut fab = TcpFabric::bind(test_setup(1)).unwrap();
        let port = fab.local_port();
        let peer = thread::spawn(move || {
            let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut hello = Vec::new();
            w_u64(&mut hello, 9);
            hello.extend_from_slice(b"nano-test");
            frame::write_frame(&mut stream, frame::HELLO, &hello).unwrap();
            frame::read_frame(&mut stream, 16).unwrap();
            frame::read_frame(&mut stream, frame::MAX_FRAME_BODY).unwrap();
            let (t, _) = frame::read_frame(&mut stream, 1 << 20).unwrap();
            assert_eq!(t, frame::RUN_PHASE);
            // Reply with a PHASE_DONE whose seq is stale garbage.
            let mut reply = Vec::new();
            w_u64(&mut reply, 999);
            frame::write_frame(&mut stream, frame::PHASE_DONE, &reply).unwrap();
            let mut buf = [0u8; 64];
            let _ = std::io::Read::read(&mut stream, &mut buf);
        });
        fab.rendezvous().unwrap();
        let mut workers = vec![tiny_worker(0)];
        let err = fab.run_phase(&mut workers, &[0], 2).unwrap_err();
        assert!(err.to_string().contains("vanished"), "{err}");
        drop(fab);
        peer.join().unwrap();
    }

    #[test]
    fn billing_only_instance_opens_no_sockets() {
        let mut fab = billing_only(SimNet::new(1e6, 0.0, 0.0, Rng::new(0)));
        assert!(fab.listener.is_none());
        fab.send_reliable_to(100, Direction::Up, 0);
        fab.end_round();
        assert_eq!(fab.stats().total_bytes(), 100);
    }

    /// `write_frame` goes through `&mut TcpStream`'s `Write` impl; keep
    /// a compile-time check that the helper stays generic enough for
    /// both sides of the protocol.
    #[test]
    fn frame_helpers_accept_any_writer() {
        let mut buf: Vec<u8> = Vec::new();
        frame::write_frame(&mut buf, frame::PING, &[]).unwrap();
        buf.flush().unwrap();
        assert_eq!(frame::decode(&buf, 0).unwrap().0, frame::PING);
    }
}
