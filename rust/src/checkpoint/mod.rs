//! Checkpointing: save/load parameter (and optimizer) tensors.
//!
//! Simple self-describing binary format (no serde/npz in the crate
//! universe): magic + version header, then per leaf: name, shape, f32
//! little-endian data, followed by a u64 FNV checksum over everything.
//! Used by the pretrain → DiLoCo warm-start flow (paper Fig 3) and the
//! CLI's `eval --ckpt`.

use crate::runtime::{Manifest, Tensors};
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"DILOCO01";

fn fnv_update(hash: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *hash ^= b as u64;
        *hash = hash.wrapping_mul(0x1000_0000_01b3);
    }
}

/// Save tensors with their manifest leaf names/shapes.
pub fn save(path: &str, manifest: &Manifest, tensors: &Tensors) -> anyhow::Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(manifest.params.len() as u32).to_le_bytes());
    for (spec, leaf) in manifest.params.iter().zip(tensors.leaves()) {
        let name = spec.name.as_bytes();
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name);
        buf.extend_from_slice(&(spec.shape.len() as u32).to_le_bytes());
        for &d in &spec.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(leaf.len() as u64).to_le_bytes());
        for &x in leaf {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    fnv_update(&mut hash, &buf);
    buf.extend_from_slice(&hash.to_le_bytes());
    let mut f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("creating {path}: {e}"))?;
    f.write_all(&buf)?;
    Ok(())
}

/// Load tensors, verifying checksum and manifest compatibility.
pub fn load(path: &str, manifest: &Manifest) -> anyhow::Result<Tensors> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening {path}: {e}"))?
        .read_to_end(&mut bytes)?;
    anyhow::ensure!(bytes.len() > MAGIC.len() + 12, "checkpoint too short");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    fnv_update(&mut hash, body);
    anyhow::ensure!(hash == stored, "checkpoint checksum mismatch");
    anyhow::ensure!(&body[..8] == MAGIC, "bad checkpoint magic");

    let mut pos = 8;
    let read_u32 = |pos: &mut usize| -> anyhow::Result<u32> {
        anyhow::ensure!(*pos + 4 <= body.len(), "truncated checkpoint");
        let v = u32::from_le_bytes(body[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
        Ok(v)
    };
    let read_u64 = |pos: &mut usize| -> anyhow::Result<u64> {
        anyhow::ensure!(*pos + 8 <= body.len(), "truncated checkpoint");
        let v = u64::from_le_bytes(body[*pos..*pos + 8].try_into().unwrap());
        *pos += 8;
        Ok(v)
    };

    let n = read_u32(&mut pos)? as usize;
    anyhow::ensure!(
        n == manifest.params.len(),
        "checkpoint has {n} leaves, manifest wants {}",
        manifest.params.len()
    );
    let mut leaves = Vec::with_capacity(n);
    for spec in &manifest.params {
        let name_len = read_u32(&mut pos)? as usize;
        anyhow::ensure!(pos + name_len <= body.len(), "truncated name");
        let name = std::str::from_utf8(&body[pos..pos + name_len])
            .map_err(|_| anyhow::anyhow!("bad leaf name"))?;
        anyhow::ensure!(
            name == spec.name,
            "leaf order mismatch: checkpoint {name:?}, manifest {:?}",
            spec.name
        );
        pos += name_len;
        let rank = read_u32(&mut pos)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut pos)? as usize);
        }
        anyhow::ensure!(
            shape == spec.shape,
            "leaf {name}: checkpoint shape {shape:?}, manifest {:?}",
            spec.shape
        );
        let count = read_u64(&mut pos)? as usize;
        anyhow::ensure!(count == spec.elements(), "leaf {name}: element count");
        anyhow::ensure!(pos + 4 * count <= body.len(), "truncated data");
        let mut data = Vec::with_capacity(count);
        for i in 0..count {
            let off = pos + 4 * i;
            data.push(f32::from_le_bytes(body[off..off + 4].try_into().unwrap()));
        }
        pos += 4 * count;
        leaves.push(data);
    }
    anyhow::ensure!(pos == body.len(), "trailing bytes in checkpoint");
    Tensors::from_leaves(manifest, leaves)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Option<(Manifest, Tensors)> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        let path = std::path::Path::new(dir).join("nano.manifest.json");
        if !path.exists() {
            return None;
        }
        let man = Manifest::load(&path).unwrap();
        let mut t = Tensors::zeros(&man);
        let mut x = 0.0f32;
        t.for_each_mut(|v| {
            *v = x.sin();
            x += 1.0;
        });
        Some((man, t))
    }

    #[test]
    fn roundtrip_exact() {
        let Some((man, t)) = fixture() else { return };
        let path = std::env::temp_dir().join("diloco_ckpt_test.bin");
        let path = path.to_str().unwrap();
        save(path, &man, &t).unwrap();
        let loaded = load(path, &man).unwrap();
        assert_eq!(&loaded, &t);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corruption_detected() {
        let Some((man, t)) = fixture() else { return };
        let path = std::env::temp_dir().join("diloco_ckpt_corrupt.bin");
        let path = path.to_str().unwrap();
        save(path, &man, &t).unwrap();
        let mut bytes = std::fs::read(path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(path, &bytes).unwrap();
        assert!(load(path, &man).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_file_errors_cleanly() {
        let Some((man, _)) = fixture() else { return };
        let err = load("/nonexistent/ckpt.bin", &man).unwrap_err();
        assert!(err.to_string().contains("opening"));
    }
}
