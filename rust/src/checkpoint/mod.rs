//! Checkpointing: parameter snapshots and full training state.
//!
//! Two self-describing binary formats (no serde/npz in the crate
//! universe), both ending in a u64 FNV checksum over everything before
//! it:
//!
//! * **`DILOCO01`** — parameter-only snapshots (`save` / `load`): magic +
//!   leaf count, then per leaf: name, shape, element count, f32
//!   little-endian data. Used by the pretrain → DiLoCo warm-start flow
//!   (paper Fig 3) and the CLI's `eval --ckpt`.
//! * **`DILOST01`** — the full [`TrainState`] record (`save_state` /
//!   `load_state`): round index, global/consensus model, per-replica
//!   models, outer-optimizer state per fragment, per-worker inner AdamW
//!   state + RNG stream cursors, per-fragment sync state, carried-over
//!   accounting, and (format version 2) the async layer's in-flight
//!   delayed contribution queue. The resume contract is *bitwise*: training
//!   2R rounds straight equals training R rounds, saving, and resuming
//!   for R more (DESIGN.md §10; enforced by the `resume_*` integration
//!   tests and the CI resume-equivalence job).
//!
//! Every length and data range read from disk is bounds-checked against
//! the remaining body and validated against the manifest shape product
//! before any allocation, so truncated, oversized, or shape-mismatched
//! files surface as `anyhow` errors — never as slice panics or absurd
//! allocations.

use crate::coordinator::opt::OuterOptSnapshot;
use crate::coordinator::stats::RoundStats;
use crate::runtime::{Manifest, Tensors};
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"DILOCO01";
const STATE_MAGIC: &[u8; 8] = b"DILOST01";
/// Version 2 appends the async scheduling layer's in-flight delayed
/// contribution queue; version-1 states (written before the queue
/// existed) load with an empty queue. Version 3 appends the per-worker
/// error-feedback residuals; version-2 states (written before error
/// feedback existed) load with no residuals, which the coordinator
/// re-initializes to zero when `stream.error_feedback` is on. Version 4
/// appends the robust-aggregation outcome columns (rejected
/// contributions, trimmed weight mass) to every stored [`RoundStats`]
/// record and the adversary's stale-replay swap buffers; pre-version-4
/// states load with zeroed columns and no parked deltas.
const STATE_VERSION: u32 = 4;
/// Sanity caps for untrusted length fields that the manifest cannot
/// bound (fragment counts, Adam step vectors, kind strings).
const MAX_FRAGMENTS: usize = 1 << 20;
const MAX_KIND_LEN: usize = 64;

/// FNV-1a 64 offset basis. Shared with `comm::frame`, which trailers
/// every TCP frame with the same checksum the checkpoint container uses.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

pub(crate) fn fnv_update(hash: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *hash ^= b as u64;
        *hash = hash.wrapping_mul(0x1000_0000_01b3);
    }
}

fn read_file(path: &str) -> anyhow::Result<Vec<u8>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening {path}: {e}"))?
        .read_to_end(&mut bytes)?;
    Ok(bytes)
}

/// Verify the trailing FNV checksum and strip it, returning the body.
fn checked_body(bytes: &[u8], magic: &[u8; 8]) -> anyhow::Result<&[u8]> {
    anyhow::ensure!(bytes.len() > magic.len() + 12, "checkpoint too short");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let mut hash = FNV_OFFSET;
    fnv_update(&mut hash, body);
    anyhow::ensure!(hash == stored, "checkpoint checksum mismatch");
    anyhow::ensure!(&body[..8] == magic, "bad checkpoint magic");
    Ok(body)
}

fn write_checked(path: &str, mut buf: Vec<u8>) -> anyhow::Result<()> {
    let mut hash = FNV_OFFSET;
    fnv_update(&mut hash, &buf);
    buf.extend_from_slice(&hash.to_le_bytes());
    let mut f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("creating {path}: {e}"))?;
    f.write_all(&buf)?;
    Ok(())
}

/// Bounds-checked cursor over a checkpoint body. Every read validates
/// against the remaining length *before* touching the slice, so a
/// truncated or length-corrupted file can never index out of bounds.
/// `pub(crate)`: `comm::tcp` decodes its frame bodies with the same
/// cursor so the TCP parser inherits the bounds discipline for free.
pub(crate) struct Reader<'a> {
    body: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(body: &'a [u8], pos: usize) -> Reader<'a> {
        Reader { body, pos }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.body.len() - self.pos
    }

    pub(crate) fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.remaining(),
            "truncated checkpoint: need {n} bytes, {} left",
            self.remaining()
        );
        let s = &self.body[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A length field that must index something in the remaining body:
    /// rejects values over `cap` before any allocation happens.
    pub(crate) fn len_capped(&mut self, cap: usize, what: &str) -> anyhow::Result<usize> {
        let n = self.u64()?;
        anyhow::ensure!(
            n <= cap as u64,
            "checkpoint {what} count {n} exceeds the plausible bound {cap}"
        );
        Ok(n as usize)
    }

    /// One f32 leaf of exactly `want` elements (validated before the
    /// data range is touched or the vector allocated).
    pub(crate) fn f32_leaf(&mut self, want: usize, what: &str) -> anyhow::Result<Vec<f32>> {
        let count = self.u64()?;
        anyhow::ensure!(
            count == want as u64,
            "{what}: checkpoint stores {count} elements, manifest shape product is {want}"
        );
        let byte_len = want
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("{what}: element count overflows"))?;
        let raw = self
            .take(byte_len)
            .map_err(|e| anyhow::anyhow!("{what}: {e}"))?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// A manifest-shaped tensor tree: leaf count + per-leaf data, each
    /// leaf validated against its manifest shape product.
    fn tensors(&mut self, manifest: &Manifest, what: &str) -> anyhow::Result<Tensors> {
        let n = self.u32()? as usize;
        anyhow::ensure!(
            n == manifest.params.len(),
            "{what}: checkpoint has {n} leaves, manifest wants {}",
            manifest.params.len()
        );
        let mut leaves = Vec::with_capacity(n);
        for spec in &manifest.params {
            leaves.push(self.f32_leaf(spec.elements(), &format!("{what}.{}", spec.name))?);
        }
        Tensors::from_leaves(manifest, leaves)
    }

    pub(crate) fn finish(self) -> anyhow::Result<()> {
        anyhow::ensure!(self.remaining() == 0, "trailing bytes in checkpoint");
        Ok(())
    }
}

pub(crate) fn w_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn w_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn w_f64(buf: &mut Vec<u8>, v: f64) {
    w_u64(buf, v.to_bits());
}

pub(crate) fn w_tensors(buf: &mut Vec<u8>, t: &Tensors) {
    w_u32(buf, t.n_leaves() as u32);
    for leaf in t.leaves() {
        w_u64(buf, leaf.len() as u64);
        for &x in leaf {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

// ---- parameter-only snapshots (DILOCO01) --------------------------------

/// Save tensors with their manifest leaf names/shapes.
pub fn save(path: &str, manifest: &Manifest, tensors: &Tensors) -> anyhow::Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    w_u32(&mut buf, manifest.params.len() as u32);
    for (spec, leaf) in manifest.params.iter().zip(tensors.leaves()) {
        let name = spec.name.as_bytes();
        w_u32(&mut buf, name.len() as u32);
        buf.extend_from_slice(name);
        w_u32(&mut buf, spec.shape.len() as u32);
        for &d in &spec.shape {
            w_u64(&mut buf, d as u64);
        }
        w_u64(&mut buf, leaf.len() as u64);
        for &x in leaf {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    write_checked(path, buf)
}

/// Load tensors, verifying checksum and manifest compatibility. Every
/// stored length is bounds-checked against the remaining body and
/// validated against the manifest shape product before the data range is
/// read, so corrupted or adversarial files error instead of panicking.
pub fn load(path: &str, manifest: &Manifest) -> anyhow::Result<Tensors> {
    let bytes = read_file(path)?;
    let body = checked_body(&bytes, MAGIC)?;
    let mut r = Reader::new(body, 8);

    let n = r.u32()? as usize;
    anyhow::ensure!(
        n == manifest.params.len(),
        "checkpoint has {n} leaves, manifest wants {}",
        manifest.params.len()
    );
    let mut leaves = Vec::with_capacity(n);
    for spec in &manifest.params {
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len).map_err(|_| {
            anyhow::anyhow!("truncated name")
        })?)
        .map_err(|_| anyhow::anyhow!("bad leaf name"))?;
        anyhow::ensure!(
            name == spec.name,
            "leaf order mismatch: checkpoint {name:?}, manifest {:?}",
            spec.name
        );
        let rank = r.u32()? as usize;
        anyhow::ensure!(
            rank == spec.shape.len(),
            "leaf {name}: checkpoint rank {rank}, manifest {}",
            spec.shape.len()
        );
        let mut shape = Vec::with_capacity(rank.min(16));
        for _ in 0..rank {
            shape.push(r.u64()? as usize);
        }
        anyhow::ensure!(
            shape == spec.shape,
            "leaf {name}: checkpoint shape {shape:?}, manifest {:?}",
            spec.shape
        );
        leaves.push(r.f32_leaf(spec.elements(), &format!("leaf {name}"))?);
    }
    r.finish()?;
    Tensors::from_leaves(manifest, leaves)
}

// ---- full training state (DILOST01) -------------------------------------

/// One worker's checkpointed inner state: model replica view, AdamW
/// moments, global step counter (drives the baked lr schedule), and the
/// batch-sampler RNG cursor — everything a resumed worker needs to
/// continue its exact trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerState {
    pub params: Tensors,
    pub opt_m: Tensors,
    pub opt_v: Tensors,
    pub step: f64,
    pub rng: [u64; 4],
}

/// One due fragment of an in-flight delayed contribution batch
/// ([`PendingSync`]): the already-averaged payload plus the worker sets
/// that adopt and get billed when the batch lands.
#[derive(Clone, Debug, PartialEq)]
pub struct PendingFragment {
    /// Fragment id inside the run's [`crate::comm::fragment::FragmentPlan`].
    pub fragment: usize,
    /// Weighted-average payload, flattened in the fragment's slice order.
    pub avg: Vec<f32>,
    /// Worker ids whose upload of this fragment landed — they adopt the
    /// freshly stepped global at apply time (upload-round roster order).
    pub landed: Vec<usize>,
    /// Worker ids billed the full-precision download at apply time: the
    /// landed workers under star, the landed group *leaders* under the
    /// hierarchical topology.
    pub down_to: Vec<usize>,
}

/// One outer contribution batch awaiting delayed application
/// (`sync.delay_rounds > 0`; DESIGN.md §11): computed and billed in its
/// upload round, folded into the global model `D` rounds later. The
/// queue is part of [`TrainState`] so a checkpoint taken with batches in
/// flight resumes bitwise.
#[derive(Clone, Debug, PartialEq)]
pub struct PendingSync {
    /// The round whose inner phase produced this batch.
    pub round: usize,
    /// Per due fragment: averaged payload + adopt/billing sets. Empty
    /// when every upload of the round dropped (the batch applies as a
    /// no-op).
    pub frags: Vec<PendingFragment>,
    /// Upload-round statistics (cosines, norms, codec error, roster,
    /// idle); `staleness` is stamped at apply time. `None` exactly when
    /// `frags` is empty.
    pub stats: Option<RoundStats>,
}

/// The full mid-run record of a DiLoCo training job at a round boundary
/// (see the module docs for the on-disk format and DESIGN.md §10 for the
/// layout rationale and determinism contract).
///
/// Covers both round-loop shapes: centralized topologies (star,
/// hierarchical) store the single global model in `global` and one outer
/// optimizer; decentralized topologies (ring, gossip) store the current
/// consensus in `global` plus one replica and one outer optimizer per
/// pool worker. Roster state is *not* stored — the active roster is a
/// pure function of `(churn schedule, round)`, so a resumed run derives
/// it deterministically.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    /// Next round index to execute (the run saved after `round` rounds).
    pub round: usize,
    /// Total rounds of the run that wrote this state. Roster derivations
    /// that depend on the run length (the churn `ramp:`) must resume
    /// with the same `rounds`, and the coordinator rejects a mismatch.
    pub total_rounds: usize,
    /// Round-loop shape this state belongs to.
    pub decentralized: bool,
    /// Global model (centralized) / consensus model (decentralized).
    pub global: Tensors,
    /// Per-worker model replicas (decentralized only; empty otherwise).
    pub replicas: Vec<Tensors>,
    /// Outer-optimizer state: one entry (centralized) or one per pool
    /// worker (decentralized). Per-fragment momentum/Adam slices live
    /// inside each snapshot's manifest-shaped tensors.
    pub outer: Vec<OuterOptSnapshot>,
    /// Per-worker inner state, indexed by worker id over the full pool
    /// (parked/departed workers included — that is what makes rejoin
    /// restore their state).
    pub workers: Vec<WorkerState>,
    /// Per-worker sync references (the last global values each worker
    /// adopted, per fragment).
    pub refs: Vec<Tensors>,
    /// pending_adopt[w][f] — worker w re-adopts fragment f at its next
    /// active round.
    pub pending_adopt: Vec<Vec<bool>>,
    /// Rounds in which each worker lost at least one fragment upload.
    pub drops_per_worker: Vec<usize>,
    /// Transfer seconds deferred into the next inner phase (overlapped
    /// streaming schedule).
    pub carry_comm_s: f64,
    /// Cumulative squared codec error (kept so the resumed run's
    /// end-of-run `codec_err_l2` covers the whole training history).
    pub codec_err_sq_total: f64,
    /// In-flight delayed contribution batches, oldest first (empty on
    /// the synchronous path and in version-1 checkpoints).
    pub pending_sync: Vec<PendingSync>,
    /// Per-worker error-feedback residuals (`stream.error_feedback`),
    /// indexed like `refs` over the full pool: what each worker's last
    /// compressed upload failed to carry, replayed into its next outer
    /// delta. Empty when error feedback is off and in pre-version-3
    /// checkpoints (the coordinator then resumes with zero residuals).
    pub residuals: Vec<Tensors>,
    /// Stale-replay attack buffers (`[adversary] attack = "stale"`;
    /// DESIGN.md §16): `(worker id, parked delta)` in strictly ascending
    /// id order, id-tagged so an *absent* buffer (attacker that has not
    /// synced yet) is distinguishable from a parked all-zero delta.
    /// Empty for every other attack, with no adversary at all, and in
    /// pre-version-4 checkpoints (a resumed stale-replay attacker then
    /// ships one honest delta before replaying, exactly like round 0).
    pub stale: Vec<(usize, Tensors)>,
}

fn w_outer(buf: &mut Vec<u8>, snap: &OuterOptSnapshot) {
    let kind = snap.kind.as_bytes();
    w_u32(buf, kind.len() as u32);
    buf.extend_from_slice(kind);
    w_u64(buf, snap.t.len() as u64);
    for &x in &snap.t {
        w_u64(buf, x);
    }
    w_u32(buf, snap.tensors.len() as u32);
    for t in &snap.tensors {
        w_tensors(buf, t);
    }
}

fn r_outer(r: &mut Reader<'_>, manifest: &Manifest) -> anyhow::Result<OuterOptSnapshot> {
    let kind_len = r.u32()? as usize;
    anyhow::ensure!(kind_len <= MAX_KIND_LEN, "outer optimizer kind name too long");
    let kind = std::str::from_utf8(r.take(kind_len)?)
        .map_err(|_| anyhow::anyhow!("bad outer optimizer kind"))?
        .to_string();
    let t_len = r.len_capped(MAX_FRAGMENTS, "adam step")?;
    let mut t = Vec::with_capacity(t_len);
    for _ in 0..t_len {
        t.push(r.u64()?);
    }
    let n_tensors = r.u32()? as usize;
    anyhow::ensure!(n_tensors <= 2, "outer optimizer stores at most 2 state tensors");
    let mut tensors = Vec::with_capacity(n_tensors);
    for i in 0..n_tensors {
        tensors.push(r.tensors(manifest, &format!("outer[{kind}].state{i}"))?);
    }
    Ok(OuterOptSnapshot { kind, t, tensors })
}

fn w_stats(buf: &mut Vec<u8>, rs: &RoundStats) {
    w_u64(buf, rs.round as u64);
    w_f64(buf, rs.cos_mean);
    w_f64(buf, rs.cos_std);
    w_f64(buf, rs.avg_delta_norm);
    w_f64(buf, rs.per_worker_norm_mean);
    w_u64(buf, rs.fragments_synced as u64);
    w_f64(buf, rs.codec_err_l2);
    w_f64(buf, rs.consensus_dist);
    w_u64(buf, rs.active_workers as u64);
    w_u64(buf, rs.staleness as u64);
    w_f64(buf, rs.idle_s);
    w_u64(buf, rs.rejected as u64);
    w_f64(buf, rs.trimmed_mass);
}

/// `version` is the containing file's format version: the robust
/// aggregation outcome columns exist only from version 4 on, and a
/// pre-version-4 record loads them as zero (no rejections — those
/// states predate the robust aggregators).
fn r_stats(r: &mut Reader<'_>, version: u32) -> anyhow::Result<RoundStats> {
    Ok(RoundStats {
        round: r.u64()? as usize,
        cos_mean: r.f64()?,
        cos_std: r.f64()?,
        avg_delta_norm: r.f64()?,
        per_worker_norm_mean: r.f64()?,
        fragments_synced: r.u64()? as usize,
        codec_err_l2: r.f64()?,
        consensus_dist: r.f64()?,
        active_workers: r.u64()? as usize,
        staleness: r.u64()? as usize,
        idle_s: r.f64()?,
        rejected: if version >= 4 { r.u64()? as usize } else { 0 },
        trimmed_mass: if version >= 4 { r.f64()? } else { 0.0 },
    })
}

fn w_pending(buf: &mut Vec<u8>, p: &PendingSync) {
    w_u64(buf, p.round as u64);
    w_u32(buf, p.frags.len() as u32);
    for f in &p.frags {
        w_u64(buf, f.fragment as u64);
        w_u64(buf, f.avg.len() as u64);
        for &x in &f.avg {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w_u64(buf, f.landed.len() as u64);
        for &w in &f.landed {
            w_u64(buf, w as u64);
        }
        w_u64(buf, f.down_to.len() as u64);
        for &w in &f.down_to {
            w_u64(buf, w as u64);
        }
    }
    buf.push(p.stats.is_some() as u8);
    if let Some(rs) = &p.stats {
        w_stats(buf, rs);
    }
}

/// One in-flight batch, every length bounds-checked: fragment ids
/// against the state's fragment count, worker-id lists against the
/// pool, payload lengths against the manifest's total element count.
/// The writer emits fragments in due order and worker ids in roster
/// order — both strictly increasing — so the reader rejects any other
/// ordering: a valid-checksum corruption repeating a fragment (which
/// would silently double-step the outer optimizer on resume) or a
/// worker id errors instead of loading.
fn r_pending(
    r: &mut Reader<'_>,
    manifest: &Manifest,
    pool: usize,
    n_frag: usize,
    version: u32,
) -> anyhow::Result<PendingSync> {
    let round = r.u64()? as usize;
    let n_frags = r.u32()? as usize;
    anyhow::ensure!(
        n_frags <= n_frag,
        "pending batch stores {n_frags} fragments, the state has {n_frag}"
    );
    let total_elems: usize = manifest.params.iter().map(|s| s.elements()).sum();
    let mut frags: Vec<PendingFragment> = Vec::with_capacity(n_frags);
    for _ in 0..n_frags {
        let fragment = r.u64()? as usize;
        anyhow::ensure!(
            fragment < n_frag,
            "pending batch names fragment {fragment} of {n_frag}"
        );
        anyhow::ensure!(
            frags.last().is_none_or(|p| p.fragment < fragment),
            "pending batch fragments out of order (fragment {fragment})"
        );
        let avg_len = r.len_capped(total_elems, "pending payload")?;
        let raw = r.take(avg_len * 4)?;
        let avg = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let mut ids = |what: &str| -> anyhow::Result<Vec<usize>> {
            let n = r.len_capped(pool, what)?;
            let mut v: Vec<usize> = Vec::with_capacity(n);
            for _ in 0..n {
                let id = r.u64()? as usize;
                anyhow::ensure!(id < pool, "pending {what} id {id} outside pool {pool}");
                anyhow::ensure!(
                    v.last().is_none_or(|&p| p < id),
                    "pending {what} ids out of roster order (id {id})"
                );
                v.push(id);
            }
            Ok(v)
        };
        let landed = ids("landed worker")?;
        let down_to = ids("download worker")?;
        frags.push(PendingFragment { fragment, avg, landed, down_to });
    }
    let stats = match r.u8()? {
        0 => None,
        1 => Some(r_stats(r, version)?),
        other => anyhow::bail!("bad pending stats flag byte {other}"),
    };
    Ok(PendingSync { round, frags, stats })
}

/// Save a full [`TrainState`] (format `DILOST01`, FNV-checksummed).
pub fn save_state(path: &str, manifest: &Manifest, st: &TrainState) -> anyhow::Result<()> {
    let pool = st.workers.len();
    anyhow::ensure!(
        st.refs.len() == pool && st.pending_adopt.len() == pool
            && st.drops_per_worker.len() == pool,
        "inconsistent TrainState: pool {pool}, refs {}, pending {}, drops {}",
        st.refs.len(),
        st.pending_adopt.len(),
        st.drops_per_worker.len()
    );
    anyhow::ensure!(
        st.residuals.is_empty() || st.residuals.len() == pool,
        "inconsistent TrainState: pool {pool}, residuals {}",
        st.residuals.len()
    );
    anyhow::ensure!(
        st.stale.iter().all(|&(w, _)| w < pool)
            && st.stale.windows(2).all(|e| e[0].0 < e[1].0),
        "inconsistent TrainState: stale-replay ids must be ascending within the pool"
    );
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(STATE_MAGIC);
    w_u32(&mut buf, STATE_VERSION);
    buf.push(st.decentralized as u8);
    w_u64(&mut buf, st.round as u64);
    w_u64(&mut buf, st.total_rounds as u64);
    w_u64(&mut buf, pool as u64);
    let n_frag = st.pending_adopt.first().map_or(0, |p| p.len());
    w_u32(&mut buf, n_frag as u32);
    w_f64(&mut buf, st.carry_comm_s);
    w_f64(&mut buf, st.codec_err_sq_total);
    w_tensors(&mut buf, &st.global);
    w_u64(&mut buf, st.replicas.len() as u64);
    for rep in &st.replicas {
        w_tensors(&mut buf, rep);
    }
    w_u64(&mut buf, st.outer.len() as u64);
    for o in &st.outer {
        w_outer(&mut buf, o);
    }
    for w in &st.workers {
        w_tensors(&mut buf, &w.params);
        w_tensors(&mut buf, &w.opt_m);
        w_tensors(&mut buf, &w.opt_v);
        w_f64(&mut buf, w.step);
        for &s in &w.rng {
            w_u64(&mut buf, s);
        }
    }
    for rf in &st.refs {
        w_tensors(&mut buf, rf);
    }
    for pa in &st.pending_adopt {
        anyhow::ensure!(
            pa.len() == n_frag,
            "inconsistent TrainState: ragged pending_adopt"
        );
        buf.extend(pa.iter().map(|&b| b as u8));
    }
    for &d in &st.drops_per_worker {
        w_u64(&mut buf, d as u64);
    }
    w_u64(&mut buf, st.pending_sync.len() as u64);
    for p in &st.pending_sync {
        w_pending(&mut buf, p);
    }
    w_u64(&mut buf, st.residuals.len() as u64);
    for res in &st.residuals {
        w_tensors(&mut buf, res);
    }
    w_u64(&mut buf, st.stale.len() as u64);
    for (w, t) in &st.stale {
        w_u64(&mut buf, *w as u64);
        w_tensors(&mut buf, t);
    }
    write_checked(path, buf)
}

/// Load a [`TrainState`], verifying checksum, version, and manifest
/// compatibility of every tensor block.
pub fn load_state(path: &str, manifest: &Manifest) -> anyhow::Result<TrainState> {
    let bytes = read_file(path)?;
    let body = checked_body(&bytes, STATE_MAGIC)?;
    let mut r = Reader::new(body, 8);

    let version = r.u32()?;
    anyhow::ensure!(
        (1..=STATE_VERSION).contains(&version),
        "unsupported TrainState version {version} (this build reads 1..={STATE_VERSION})"
    );
    let decentralized = match r.u8()? {
        0 => false,
        1 => true,
        other => anyhow::bail!("bad TrainState mode byte {other}"),
    };
    let round = r.u64()? as usize;
    let total_rounds = r.u64()? as usize;
    // Every worker costs at least three manifest-shaped tensor blocks
    // plus its step and RNG cursor on disk, so the remaining body length
    // divided by that footprint bounds the pool *tightly* — a corrupted
    // or adversarial pool field cannot trigger an allocation larger than
    // a small fraction of the file it arrived in.
    let tensors_bytes: usize = 4 + manifest
        .params
        .iter()
        .map(|s| 8 + 4 * s.elements())
        .sum::<usize>();
    let per_worker = 3 * tensors_bytes + 8 + 32;
    let pool = r.len_capped(r.remaining() / per_worker.max(1), "worker pool")?;
    anyhow::ensure!(pool >= 1, "TrainState has an empty worker pool");
    let n_frag = r.u32()? as usize;
    anyhow::ensure!(
        (1..=MAX_FRAGMENTS).contains(&n_frag),
        "TrainState fragment count {n_frag} out of range"
    );
    let carry_comm_s = r.f64()?;
    let codec_err_sq_total = r.f64()?;
    let global = r.tensors(manifest, "global")?;
    let n_replicas = r.len_capped(pool, "replica")?;
    anyhow::ensure!(
        if decentralized { n_replicas == pool } else { n_replicas == 0 },
        "TrainState stores {n_replicas} replicas for a pool of {pool} \
         (decentralized = {decentralized})"
    );
    let mut replicas = Vec::with_capacity(n_replicas);
    for i in 0..n_replicas {
        replicas.push(r.tensors(manifest, &format!("replica[{i}]"))?);
    }
    let n_outer = r.len_capped(pool, "outer optimizer")?;
    anyhow::ensure!(
        n_outer == if decentralized { pool } else { 1 },
        "TrainState stores {n_outer} outer optimizers for a pool of {pool} \
         (decentralized = {decentralized})"
    );
    let mut outer = Vec::with_capacity(n_outer);
    for _ in 0..n_outer {
        outer.push(r_outer(&mut r, manifest)?);
    }
    let mut workers = Vec::with_capacity(pool);
    for i in 0..pool {
        let params = r.tensors(manifest, &format!("worker[{i}].params"))?;
        let opt_m = r.tensors(manifest, &format!("worker[{i}].opt_m"))?;
        let opt_v = r.tensors(manifest, &format!("worker[{i}].opt_v"))?;
        let step = r.f64()?;
        let mut rng = [0u64; 4];
        for s in &mut rng {
            *s = r.u64()?;
        }
        workers.push(WorkerState { params, opt_m, opt_v, step, rng });
    }
    let mut refs = Vec::with_capacity(pool);
    for i in 0..pool {
        refs.push(r.tensors(manifest, &format!("refs[{i}]"))?);
    }
    let mut pending_adopt = Vec::with_capacity(pool);
    for _ in 0..pool {
        let row = r.take(n_frag)?;
        let mut flags = Vec::with_capacity(n_frag);
        for &b in row {
            anyhow::ensure!(b <= 1, "bad pending_adopt flag byte {b}");
            flags.push(b == 1);
        }
        pending_adopt.push(flags);
    }
    let mut drops_per_worker = Vec::with_capacity(pool);
    for _ in 0..pool {
        drops_per_worker.push(r.u64()? as usize);
    }
    // Version 2: the async layer's in-flight delayed contribution queue
    // (a version-1 state predates the queue and resumes with it empty).
    let mut pending_sync = Vec::new();
    if version >= 2 {
        // Every batch costs at least round(8) + frag count(4) + stats
        // flag(1) bytes, bounding the count tightly by the body.
        let n_pending = r.len_capped(r.remaining() / 13, "pending sync")?;
        for _ in 0..n_pending {
            pending_sync.push(r_pending(&mut r, manifest, pool, n_frag, version)?);
        }
    }
    // Version 3: per-worker error-feedback residuals. Absent or zero
    // entries mean the run had error feedback off (or predates it) —
    // the coordinator resumes with zero residuals in that case.
    let mut residuals = Vec::new();
    if version >= 3 {
        let n_res = r.len_capped(pool, "residual")?;
        anyhow::ensure!(
            n_res == 0 || n_res == pool,
            "TrainState stores {n_res} residuals for a pool of {pool}"
        );
        for i in 0..n_res {
            residuals.push(r.tensors(manifest, &format!("residual[{i}]"))?);
        }
    }
    // Version 4: the stale-replay attack's parked deltas, one per
    // attacker that has synced at least once. Ids are validated against
    // the pool and must be strictly ascending — a valid-checksum
    // corruption duplicating an id (which would silently overwrite one
    // attacker's buffer with another's) errors instead of loading.
    let mut stale: Vec<(usize, Tensors)> = Vec::new();
    if version >= 4 {
        let n_stale = r.len_capped(pool, "stale-replay buffer")?;
        for _ in 0..n_stale {
            let w = r.u64()? as usize;
            anyhow::ensure!(w < pool, "stale-replay id {w} outside pool {pool}");
            anyhow::ensure!(
                stale.last().is_none_or(|(p, _)| *p < w),
                "stale-replay ids out of order (id {w})"
            );
            stale.push((w, r.tensors(manifest, &format!("stale[{w}]"))?));
        }
    }
    r.finish()?;
    Ok(TrainState {
        round,
        total_rounds,
        decentralized,
        global,
        replicas,
        outer,
        workers,
        refs,
        pending_adopt,
        drops_per_worker,
        carry_comm_s,
        codec_err_sq_total,
        pending_sync,
        residuals,
        stale,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{LeafSpec, ManifestConfig};
    use std::collections::BTreeMap;

    /// A synthetic two-leaf manifest — the negative-path tests must run
    /// everywhere, not only on artifact-capable machines.
    fn tiny_manifest() -> Manifest {
        Manifest {
            config: ManifestConfig {
                name: "tiny".into(),
                kernels: "pallas".into(),
                n_layers: 1,
                d_model: 2,
                n_heads: 1,
                d_head: 2,
                vocab_size: 8,
                seq_len: 4,
                batch_size: 1,
                param_count: 6,
                peak_lr: 0.1,
                warmup_steps: 1,
                total_steps: 10,
                weight_decay: 0.0,
            },
            params: vec![
                LeafSpec { name: "w.embed".into(), shape: vec![2, 2] },
                LeafSpec { name: "w.out".into(), shape: vec![2] },
            ],
            artifacts: BTreeMap::new(),
        }
    }

    fn tiny_tensors() -> Tensors {
        Tensors::from_raw(vec![vec![1.0, -2.0, 3.0, -4.0], vec![0.5, 0.25]])
    }

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("diloco_{name}_{}.bin", std::process::id()))
            .to_str()
            .unwrap()
            .to_string()
    }

    /// Strip the checksum, let the caller mutate the body, re-checksum.
    /// This is how the negative-path tests craft structurally corrupt
    /// files that still pass the checksum gate — the exact shape of an
    /// on-disk corruption the old loader turned into a slice panic.
    fn rewrite_body(path: &str, mutate: impl FnOnce(&mut Vec<u8>)) {
        let bytes = std::fs::read(path).unwrap();
        let mut body = bytes[..bytes.len() - 8].to_vec();
        mutate(&mut body);
        let mut hash = 0xcbf2_9ce4_8422_2325u64;
        fnv_update(&mut hash, &body);
        body.extend_from_slice(&hash.to_le_bytes());
        std::fs::write(path, &body).unwrap();
    }

    /// Byte offset of leaf 0's u64 element-count field in a DILOCO01
    /// file built from `tiny_manifest`: magic(8) + n(4) + name_len(4) +
    /// name + rank(4) + shape dims (8 each).
    fn leaf0_count_offset(man: &Manifest) -> usize {
        8 + 4 + 4 + man.params[0].name.len() + 4 + 8 * man.params[0].shape.len()
    }

    #[test]
    fn roundtrip_exact_synthetic() {
        let man = tiny_manifest();
        let t = tiny_tensors();
        let path = tmp("ckpt_rt");
        save(&path, &man, &t).unwrap();
        let loaded = load(&path, &man).unwrap();
        assert_eq!(&loaded, &t);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_detected() {
        let man = tiny_manifest();
        let path = tmp("ckpt_corrupt");
        save(&path, &man, &tiny_tensors()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path, &man).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors_cleanly() {
        let man = tiny_manifest();
        let err = load("/nonexistent/ckpt.bin", &man).unwrap_err();
        assert!(err.to_string().contains("opening"));
    }

    #[test]
    fn truncated_leaf_data_is_an_error_not_a_panic() {
        // Cut the file mid-way through leaf 0's data (checksum rebuilt so
        // only the structural validation can catch it).
        let man = tiny_manifest();
        let path = tmp("ckpt_trunc");
        save(&path, &man, &tiny_tensors()).unwrap();
        rewrite_body(&path, |body| {
            let cut = leaf0_count_offset(&man) + 8 + 4 * 2; // 2 of 4 elements
            body.truncate(cut);
        });
        let err = load(&path, &man).unwrap_err();
        assert!(
            format!("{err:#}").contains("truncated"),
            "unexpected error: {err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn oversized_leaf_length_is_an_error_not_an_allocation() {
        // An absurd stored element count (quadrillions) must be rejected
        // by the shape-product validation before any allocation or slice
        // indexing — this was the out-of-bounds panic path.
        let man = tiny_manifest();
        let path = tmp("ckpt_oversize");
        save(&path, &man, &tiny_tensors()).unwrap();
        let off = leaf0_count_offset(&man);
        rewrite_body(&path, |body| {
            body[off..off + 8].copy_from_slice(&(u64::MAX / 4).to_le_bytes());
        });
        let err = load(&path, &man).unwrap_err();
        assert!(
            format!("{err:#}").contains("shape product"),
            "unexpected error: {err:#}"
        );
        // A subtler lie: a count that fits the body but disagrees with
        // the manifest shape product.
        save(&path, &man, &tiny_tensors()).unwrap();
        rewrite_body(&path, |body| {
            body[off..off + 8].copy_from_slice(&3u64.to_le_bytes());
        });
        let err = load(&path, &man).unwrap_err();
        assert!(format!("{err:#}").contains("shape product"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let man = tiny_manifest();
        let path = tmp("ckpt_shape");
        save(&path, &man, &tiny_tensors()).unwrap();
        // First dim of leaf 0's shape: after magic + n + name_len + name + rank.
        let off = 8 + 4 + 4 + man.params[0].name.len() + 4;
        rewrite_body(&path, |body| {
            body[off..off + 8].copy_from_slice(&7u64.to_le_bytes());
        });
        let err = load(&path, &man).unwrap_err();
        assert!(
            format!("{err:#}").contains("shape"),
            "unexpected error: {err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    fn tiny_state(decentralized: bool) -> TrainState {
        let man = tiny_manifest();
        let zeros = Tensors::zeros(&man);
        let t = tiny_tensors();
        let pool = 2;
        let snap = OuterOptSnapshot {
            kind: "nesterov".into(),
            t: Vec::new(),
            tensors: vec![t.clone()],
        };
        TrainState {
            round: 3,
            total_rounds: 6,
            decentralized,
            global: t.clone(),
            replicas: if decentralized { vec![t.clone(), zeros.clone()] } else { Vec::new() },
            outer: if decentralized {
                vec![snap.clone(), snap.clone()]
            } else {
                vec![snap]
            },
            workers: (0..pool)
                .map(|i| WorkerState {
                    params: t.clone(),
                    opt_m: zeros.clone(),
                    opt_v: zeros.clone(),
                    step: 42.0 + i as f64,
                    rng: [i as u64, 2, 3, 4],
                })
                .collect(),
            refs: vec![t.clone(), t.clone()],
            pending_adopt: vec![vec![true, false], vec![false, true]],
            drops_per_worker: vec![1, 0],
            carry_comm_s: 0.5,
            codec_err_sq_total: 0.25,
            pending_sync: Vec::new(),
            residuals: Vec::new(),
            stale: Vec::new(),
        }
    }

    fn tiny_pending() -> PendingSync {
        PendingSync {
            round: 2,
            frags: vec![
                PendingFragment {
                    fragment: 0,
                    avg: vec![0.5, -1.5, 2.0],
                    landed: vec![0, 1],
                    down_to: vec![0],
                },
                PendingFragment {
                    fragment: 1,
                    avg: vec![3.25],
                    landed: vec![1],
                    down_to: vec![1],
                },
            ],
            stats: Some(RoundStats {
                round: 2,
                cos_mean: 0.5,
                cos_std: 0.1,
                avg_delta_norm: 1.25,
                per_worker_norm_mean: 2.5,
                fragments_synced: 2,
                codec_err_l2: 0.0,
                consensus_dist: 0.0,
                active_workers: 2,
                staleness: 0,
                idle_s: 0.75,
                rejected: 1,
                trimmed_mass: 0.25,
            }),
        }
    }

    #[test]
    fn train_state_roundtrips_both_modes() {
        let man = tiny_manifest();
        for (tag, dec) in [("cen", false), ("dec", true)] {
            let st = tiny_state(dec);
            let path = tmp(&format!("state_rt_{tag}"));
            save_state(&path, &man, &st).unwrap();
            let loaded = load_state(&path, &man).unwrap();
            assert_eq!(loaded, st);
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn train_state_roundtrips_pending_sync_queue() {
        // A checkpoint taken with delayed contributions in flight must
        // restore the queue exactly — payloads, adopt/billing sets, and
        // the upload-round statistics (DESIGN.md §11 resume contract).
        let man = tiny_manifest();
        let mut st = tiny_state(false);
        st.pending_sync = vec![
            tiny_pending(),
            PendingSync { round: 3, frags: Vec::new(), stats: None },
        ];
        let path = tmp("state_pending");
        save_state(&path, &man, &st).unwrap();
        let loaded = load_state(&path, &man).unwrap();
        assert_eq!(loaded, st);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pending_sync_rejects_corrupt_lengths() {
        // Crafted valid-checksum corruptions of the queue section must
        // error, never allocate absurdly or index out of bounds. The
        // section sits at the very end of the body, so offsets are
        // computed from the tail.
        let man = tiny_manifest();
        let mut st = tiny_state(false);
        st.pending_sync = vec![tiny_pending()];
        let base = tmp("state_pending_neg");
        save_state(&base, &man, &st).unwrap();
        // The queue's count field starts where an empty-queue save ends
        // minus the trailing residual count (8), stale count (8), and
        // its own 8 bytes: everything before it is identical.
        let mut empty = st.clone();
        empty.pending_sync.clear();
        let empty_path = tmp("state_pending_empty");
        save_state(&empty_path, &man, &empty).unwrap();
        let empty_body_len = std::fs::read(&empty_path).unwrap().len() - 8;
        std::fs::remove_file(&empty_path).ok();
        let count_off = empty_body_len - 24;

        // An absurd batch count must be rejected before allocation.
        rewrite_body(&base, |body| {
            body[count_off..count_off + 8]
                .copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        });
        let err = load_state(&base, &man).unwrap_err();
        assert!(format!("{err:#}").contains("pending"), "{err:#}");

        // An oversized payload length must be rejected against the
        // manifest's element total (frag 0's avg_len sits after
        // count + round + n_frags + fragment id).
        save_state(&base, &man, &st).unwrap();
        let avg_len_off = count_off + 8 + 8 + 4 + 8;
        rewrite_body(&base, |body| {
            body[avg_len_off..avg_len_off + 8]
                .copy_from_slice(&(u64::MAX / 8).to_le_bytes());
        });
        let err = load_state(&base, &man).unwrap_err();
        assert!(format!("{err:#}").contains("payload"), "{err:#}");

        // A landed id outside the pool is rejected.
        save_state(&base, &man, &st).unwrap();
        let landed_id_off = avg_len_off + 8 + 4 * 3 + 8; // avg data + landed count
        rewrite_body(&base, |body| {
            body[landed_id_off..landed_id_off + 8]
                .copy_from_slice(&99u64.to_le_bytes());
        });
        let err = load_state(&base, &man).unwrap_err();
        assert!(format!("{err:#}").contains("pool"), "{err:#}");

        // A duplicated (out-of-order) fragment id is rejected — it
        // would silently double-step the outer optimizer on resume.
        // Frag 1's id sits after frag 0's full record: avg_len(8) +
        // avg(3×4) + landed count(8) + 2 ids(16) + down count(8) + 1
        // id(8).
        save_state(&base, &man, &st).unwrap();
        let frag1_id_off = avg_len_off + 8 + 4 * 3 + 8 + 16 + 8 + 8;
        rewrite_body(&base, |body| {
            body[frag1_id_off..frag1_id_off + 8].copy_from_slice(&0u64.to_le_bytes());
        });
        let err = load_state(&base, &man).unwrap_err();
        assert!(format!("{err:#}").contains("out of order"), "{err:#}");

        // A duplicated landed worker id is rejected the same way.
        save_state(&base, &man, &st).unwrap();
        let landed_id1_off = landed_id_off + 8;
        rewrite_body(&base, |body| {
            body[landed_id1_off..landed_id1_off + 8]
                .copy_from_slice(&0u64.to_le_bytes());
        });
        let err = load_state(&base, &man).unwrap_err();
        assert!(format!("{err:#}").contains("roster order"), "{err:#}");
        std::fs::remove_file(&base).ok();
    }

    #[test]
    fn version_one_states_load_with_empty_queue() {
        // A pre-async (version 1) TrainState has no queue section; it
        // must load as a state with no batches in flight. Crafted by
        // rewriting a v4 save: version field back to 1, the trailing
        // empty-stale, empty-residual, and empty-queue counts stripped.
        let man = tiny_manifest();
        let st = tiny_state(false);
        let path = tmp("state_v1");
        save_state(&path, &man, &st).unwrap();
        rewrite_body(&path, |body| {
            body[8..12].copy_from_slice(&1u32.to_le_bytes());
            let n = body.len();
            body.truncate(n - 24);
        });
        let loaded = load_state(&path, &man).unwrap();
        assert_eq!(loaded, st);
        // An unknown future version is still rejected.
        save_state(&path, &man, &st).unwrap();
        rewrite_body(&path, |body| {
            body[8..12].copy_from_slice(&99u32.to_le_bytes());
        });
        assert!(load_state(&path, &man).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_two_states_load_with_empty_residuals() {
        // A pre-error-feedback (version 2) TrainState has no residual
        // section; it must load with no residuals (the coordinator then
        // re-initializes them to zero if error feedback is on). Crafted
        // by rewriting a v4 save: version field back to 2, then
        // stripping — back to front — the empty-stale count, the
        // empty-residual count, and the two v4 outcome columns at the
        // tail of the pending batch's stats record — the exact inverse
        // of what the v4 writer appends.
        let man = tiny_manifest();
        let mut st = tiny_state(false);
        st.pending_sync = vec![tiny_pending()];
        let path = tmp("state_v2");
        save_state(&path, &man, &st).unwrap();
        rewrite_body(&path, |body| {
            body[8..12].copy_from_slice(&2u32.to_le_bytes());
            let n = body.len();
            body.truncate(n - 32);
        });
        let loaded = load_state(&path, &man).unwrap();
        // Pending queue intact; residuals empty; the v2 stats record
        // predates the outcome columns, which default to zero.
        let mut expected = st.clone();
        let rs = expected.pending_sync[0].stats.as_mut().unwrap();
        rs.rejected = 0;
        rs.trimmed_mass = 0.0;
        assert_eq!(loaded, expected);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn train_state_roundtrips_error_feedback_residuals() {
        let man = tiny_manifest();
        let mut st = tiny_state(false);
        st.residuals = vec![tiny_tensors(), Tensors::zeros(&man)];
        let path = tmp("state_residuals");
        save_state(&path, &man, &st).unwrap();
        let loaded = load_state(&path, &man).unwrap();
        assert_eq!(loaded, st);
        std::fs::remove_file(&path).ok();

        // A residual count that matches neither 0 nor the pool is a
        // structural error, not a partial load. The count field's offset
        // is found from a save identical in everything but residuals:
        // with those empty, the count is the save's second-to-last body
        // u64 (only the empty-stale count follows it).
        let mut empty_res = st.clone();
        empty_res.residuals.clear();
        let empty_path = tmp("state_residuals_empty");
        save_state(&empty_path, &man, &empty_res).unwrap();
        let count_off = std::fs::read(&empty_path).unwrap().len() - 8 - 16;
        std::fs::remove_file(&empty_path).ok();
        save_state(&path, &man, &st).unwrap();
        rewrite_body(&path, |body| {
            body[count_off..count_off + 8].copy_from_slice(&1u64.to_le_bytes());
        });
        let err = load_state(&path, &man).unwrap_err();
        assert!(format!("{err:#}").contains("residual"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_three_states_load_without_stale_buffers() {
        // A pre-adversary (version 3) TrainState has no stale-replay
        // section; it must load with no parked deltas. Crafted by
        // rewriting a v4 save: version field back to 3, the trailing
        // empty-stale count stripped — the residual section before it
        // is untouched.
        let man = tiny_manifest();
        let mut st = tiny_state(false);
        st.residuals = vec![tiny_tensors(), Tensors::zeros(&man)];
        let path = tmp("state_v3");
        save_state(&path, &man, &st).unwrap();
        rewrite_body(&path, |body| {
            body[8..12].copy_from_slice(&3u32.to_le_bytes());
            let n = body.len();
            body.truncate(n - 8);
        });
        let loaded = load_state(&path, &man).unwrap();
        assert_eq!(loaded, st); // residuals intact, stale empty
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn train_state_roundtrips_stale_replay_buffers() {
        let man = tiny_manifest();
        let mut st = tiny_state(false);
        st.stale = vec![(0, tiny_tensors()), (1, Tensors::zeros(&man))];
        let path = tmp("state_stale");
        save_state(&path, &man, &st).unwrap();
        assert_eq!(load_state(&path, &man).unwrap(), st);

        // A sparse buffer set (only one attacker has synced) round-trips
        // too — the id tag is what makes that unambiguous.
        st.stale = vec![(1, tiny_tensors())];
        save_state(&path, &man, &st).unwrap();
        assert_eq!(load_state(&path, &man).unwrap(), st);

        // The writer refuses inconsistent buffers outright.
        let mut bad = st.clone();
        bad.stale = vec![(5, tiny_tensors())]; // outside the pool of 2
        assert!(save_state(&path, &man, &bad).is_err());

        // Crafted valid-checksum corruptions of entry 1's id. The id
        // starts exactly where a one-entry save's body ends — the two
        // bodies are identical through the first entry (the count field
        // differs in value, not width).
        st.stale = vec![(0, tiny_tensors()), (1, Tensors::zeros(&man))];
        let one = {
            let mut s = st.clone();
            s.stale.truncate(1);
            s
        };
        let one_path = tmp("state_stale_one");
        save_state(&one_path, &man, &one).unwrap();
        let id1_off = std::fs::read(&one_path).unwrap().len() - 8;
        std::fs::remove_file(&one_path).ok();

        // A duplicated (out-of-order) id would silently overwrite one
        // attacker's buffer with another's — rejected.
        save_state(&path, &man, &st).unwrap();
        rewrite_body(&path, |body| {
            body[id1_off..id1_off + 8].copy_from_slice(&0u64.to_le_bytes());
        });
        let err = load_state(&path, &man).unwrap_err();
        assert!(format!("{err:#}").contains("out of order"), "{err:#}");

        // An id outside the pool is rejected.
        save_state(&path, &man, &st).unwrap();
        rewrite_body(&path, |body| {
            body[id1_off..id1_off + 8].copy_from_slice(&99u64.to_le_bytes());
        });
        let err = load_state(&path, &man).unwrap_err();
        assert!(format!("{err:#}").contains("outside pool"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn train_state_rejects_corruption_and_truncation() {
        let man = tiny_manifest();
        let st = tiny_state(true);
        let path = tmp("state_neg");
        save_state(&path, &man, &st).unwrap();

        // Bit flip → checksum.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0xFF;
        let flipped = tmp("state_neg_flip");
        std::fs::write(&flipped, &bytes).unwrap();
        assert!(load_state(&flipped, &man).is_err());
        std::fs::remove_file(&flipped).ok();

        // Structural truncation with a valid checksum.
        save_state(&path, &man, &st).unwrap();
        rewrite_body(&path, |body| {
            let n = body.len();
            body.truncate(n - 10);
        });
        assert!(load_state(&path, &man).is_err());

        // Wrong magic: a params checkpoint is not a TrainState.
        let params_path = tmp("state_neg_params");
        save(&params_path, &man, &tiny_tensors()).unwrap();
        let err = load_state(&params_path, &man).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
        std::fs::remove_file(&params_path).ok();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn train_state_mode_consistency_enforced() {
        // A decentralized flag with no replicas (or vice versa) is a
        // config/state mismatch, not a crash.
        let man = tiny_manifest();
        let mut st = tiny_state(false);
        st.decentralized = true; // now inconsistent: 0 replicas
        let path = tmp("state_mode");
        save_state(&path, &man, &st).unwrap();
        assert!(load_state(&path, &man).is_err());
        std::fs::remove_file(&path).ok();
    }
}
