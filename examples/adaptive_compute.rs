//! Adaptive compute pool (paper Fig 7 as a scenario, not a bench).
//!
//! Models the paper's motivating deployments — preemptible machines,
//! karma-scheduled clusters, volunteer pools — by changing the number of
//! active islands mid-training and showing that final quality tracks
//! total compute, not the schedule's shape.
//!
//!   cargo run --release --example adaptive_compute

use diloco::config::{ComputeSchedule, ExperimentConfig};
use diloco::coordinator::Coordinator;
use diloco::runtime::Runtime;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".into());
    let mut base = ExperimentConfig::paper_default(&dir, "nano");
    base.workers = 8;
    base.inner_steps = 15;
    base.rounds = 8;
    base.pretrain_steps = 30;
    base.data.non_iid = false; // the paper's adaptive study is i.i.d.
    base.eval_every_rounds = 2;

    let rt = Arc::new(Runtime::load(&base.artifacts_dir, &base.model)?);

    // A volunteer pool that doubles when evening volunteers join, and a
    // karma cluster that halves after quota is spent.
    let scenarios: Vec<(&str, ComputeSchedule)> = vec![
        ("volunteers join (4→8)", ComputeSchedule::Step { first: 4, second: 8 }),
        ("karma quota spent (8→4)", ComputeSchedule::Step { first: 8, second: 4 }),
        ("preemptible ramp-up (1→8)", ComputeSchedule::Ramp { from: 1, to: 8 }),
        ("graceful drain (8→1)", ComputeSchedule::Ramp { from: 8, to: 1 }),
    ];

    println!("schedule                     worker_rounds  final_ppl");
    println!("---------------------------  -------------  ---------");
    let mut results = Vec::new();
    for (name, schedule) in scenarios {
        let mut cfg = base.clone();
        cfg.schedule = schedule.clone();
        let wr = schedule.total_worker_rounds(cfg.rounds);
        let coord = Coordinator::new(cfg, rt.clone())?;
        let report = coord.run()?;
        let ppl = report.metrics.final_ppl();
        println!("{name:<27}  {wr:>13}  {ppl:>9.3}");
        results.push((name, wr, ppl));
    }

    // The paper's takeaway: equal-compute schedules land close together.
    let (n1, w1, p1) = results[0];
    let (n2, w2, p2) = results[1];
    assert_eq!(w1, w2, "doubling and halving must spend equal compute");
    println!(
        "\nequal-compute pair [{n1}] vs [{n2}]: ppl {p1:.3} vs {p2:.3} \
         (paper: such pairs match closely)"
    );
    Ok(())
}
