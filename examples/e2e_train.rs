//! End-to-end validation driver (system-prompt deliverable): train a
//! multi-million-parameter transformer with the full DiLoCo stack on the
//! synthetic corpus for a few hundred steps and log the loss curve.
//!
//! Defaults to the `micro` tier; set E2E_MODEL=tiny for the ~7M-parameter
//! run recorded in EXPERIMENTS.md (≈30–40 min on the 1-core testbed), or
//! E2E_MODEL=nano for a fast smoke. Writes loss/eval CSVs plus a final
//! checkpoint under runs/e2e/.
//!
//!   make artifacts && cargo run --release --example e2e_train

use diloco::config::{ComputeSchedule, ExperimentConfig};
use diloco::coordinator::Coordinator;
use diloco::runtime::Runtime;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".into());
    let model = std::env::var("E2E_MODEL").unwrap_or_else(|_| "micro".into());

    let mut cfg = ExperimentConfig::paper_default(&dir, &model);
    cfg.seed = 0;
    cfg.workers = 8;
    cfg.schedule = ComputeSchedule::Constant(8);
    cfg.data.non_iid = true;
    cfg.data.n_topics = 8;
    match model.as_str() {
        // ~7M params, 16×128 batches: the EXPERIMENTS.md §E2E run.
        "tiny" => {
            cfg.inner_steps = 25;
            cfg.rounds = 8;
            cfg.pretrain_steps = 75; // total 275 steps/worker path
            cfg.data.n_docs = 600;
            cfg.data.doc_len = 400;
            cfg.eval_every_rounds = 1;
            cfg.eval_batches = 2;
        }
        "micro" => {
            cfg.inner_steps = 25;
            cfg.rounds = 8;
            cfg.pretrain_steps = 75;
            cfg.data.n_docs = 400;
            cfg.data.doc_len = 250;
            cfg.eval_every_rounds = 1;
            cfg.eval_batches = 3;
        }
        _ => {
            cfg.inner_steps = 20;
            cfg.rounds = 6;
            cfg.pretrain_steps = 60;
        }
    }

    let rt = Arc::new(Runtime::load(&cfg.artifacts_dir, &cfg.model)?);
    let mcfg = &rt.manifest.config;
    println!(
        "e2e: {} — {} params, batch {}×{}, vocab {}, k={} H={} T={} (+{} pretrain)",
        mcfg.name,
        mcfg.param_count,
        mcfg.batch_size,
        mcfg.seq_len,
        mcfg.vocab_size,
        cfg.workers,
        cfg.inner_steps,
        cfg.rounds,
        cfg.pretrain_steps
    );

    let t0 = std::time::Instant::now();
    let coord = Coordinator::new(cfg.clone(), rt.clone())?;
    let report = coord.run()?;
    let wall = t0.elapsed().as_secs_f64();

    let m = &report.metrics;
    println!("\nloss curve (every 10th step):");
    for (i, l) in m.loss_curve.iter().enumerate() {
        if i % 10 == 0 || i + 1 == m.loss_curve.len() {
            println!("  step {i:>5}  loss {l:.4}");
        }
    }
    println!("\neval curve:");
    for p in &m.eval_curve {
        println!("  step {:>5}  nll {:.4}  ppl {:.3}", p.step, p.mean_nll, p.ppl);
    }
    println!(
        "\nwall {wall:.1}s | sim compute {:.1}s + comm {:.1}s | \
         {} msgs, {:.2} MB, {} dropped | coordinator overhead {:.2}%",
        m.sim_compute_seconds,
        m.sim_comm_seconds,
        m.comm_messages,
        m.comm_bytes as f64 / 1e6,
        m.comm_dropped,
        100.0 * m.phases.overhead_fraction()
    );
    println!(
        "outer-gradient cosine (first→last round): {:.3} → {:.3}",
        report.round_stats.first().map(|s| s.cos_mean).unwrap_or(f64::NAN),
        report.round_stats.last().map(|s| s.cos_mean).unwrap_or(f64::NAN)
    );

    std::fs::create_dir_all("runs/e2e")?;
    m.write_curves("runs/e2e")?;
    diloco::checkpoint::save(
        &format!("runs/e2e/{model}.final.ckpt"),
        &rt.manifest,
        &report.final_params,
    )?;
    println!("curves + checkpoint written under runs/e2e/");

    // The run must demonstrably learn — this is the e2e acceptance gate.
    let first = m.eval_curve.first().map(|p| p.ppl).unwrap_or(f64::NAN);
    let last = m.final_ppl();
    anyhow::ensure!(
        last < 0.8 * first,
        "e2e failed to learn: ppl {first:.2} → {last:.2}"
    );
    println!("e2e OK: ppl {first:.2} → {last:.2}");
    Ok(())
}
