//! Quickstart — the smallest end-to-end DiLoCo run.
//!
//! Loads the `nano` artifact set, trains 4 workers on non-i.i.d. topic
//! shards for a few rounds, and prints the PPL curve plus the
//! communication bill. Mirrors the README's first example.
//!
//! Run with:  make artifacts && cargo run --release --example quickstart

use diloco::config::ExperimentConfig;
use diloco::coordinator::Coordinator;
use diloco::runtime::Runtime;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".into());

    // 1. Describe the experiment (all knobs have paper-default values).
    let mut cfg = ExperimentConfig::paper_default(&dir, "nano");
    cfg.workers = 4;
    cfg.schedule = diloco::config::ComputeSchedule::Constant(4);
    cfg.inner_steps = 20; // H — communicate every 20 inner steps
    cfg.rounds = 6; // T
    cfg.pretrain_steps = 40;
    cfg.data.non_iid = true;

    // 2. Load the AOT artifacts (python ran once at `make artifacts`;
    //    from here on the stack is rust + PJRT only).
    let rt = Arc::new(Runtime::load(&cfg.artifacts_dir, &cfg.model)?);
    println!(
        "model: {} ({} params), kernels = {}",
        rt.manifest.config.name,
        rt.manifest.config.param_count,
        rt.manifest.config.kernels,
    );

    // 3. Run.
    let coord = Coordinator::new(cfg, rt)?;
    let report = coord.run()?;

    // 4. Inspect.
    println!("\nvalidation perplexity:");
    for p in &report.metrics.eval_curve {
        println!("  step {:>4}  ppl {:8.3}", p.step, p.ppl);
    }
    let m = &report.metrics;
    println!(
        "\ncommunicated {:.2} MB in {} messages over {} rounds \
         (vs {:.2} MB for per-step data-parallelism)",
        m.comm_bytes as f64 / 1e6,
        m.comm_messages,
        report.round_stats.len(),
        // DP would ship one gradient per worker per *inner* step:
        (coord.runtime().manifest.param_bytes() * 4 * 2 * 120) as f64 / 1e6,
    );
    println!(
        "outer-gradient cosine similarity (round 0 → last): {:.3} → {:.3}",
        report.round_stats.first().map(|s| s.cos_mean).unwrap_or(f64::NAN),
        report.round_stats.last().map(|s| s.cos_mean).unwrap_or(f64::NAN),
    );
    Ok(())
}
