//! Heterogeneous, unreliable islands — the paper's motivating deployment.
//!
//! Combines three robustness mechanisms in one scenario: islands in
//! "distant regions" (slow WAN: 200 Mb/s, 150 ms latency), flaky uplinks
//! (30% outer-gradient drop), and pruned outer gradients (50% sign
//! pruning) to respect the thin pipes. Reports what actually crossed the
//! fabric and what the fault injection did to quality — the argument for
//! why H≫1 makes geo-distributed training viable at all.
//!
//!   cargo run --release --example heterogeneous_islands

use diloco::config::ExperimentConfig;
use diloco::coordinator::Coordinator;
use diloco::runtime::Runtime;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".into());
    let mut cfg = ExperimentConfig::paper_default(&dir, "nano");
    cfg.workers = 8;
    cfg.schedule = diloco::config::ComputeSchedule::Constant(8);
    cfg.inner_steps = 20;
    cfg.rounds = 8;
    cfg.pretrain_steps = 40;
    cfg.data.non_iid = true; // each region has its own data distribution
    // A poor cross-region fabric.
    cfg.comm.bandwidth_bps = 200e6 / 8.0; // 200 Mb/s
    cfg.comm.latency_s = 0.150;
    cfg.comm.drop_prob = 0.3;
    cfg.prune_frac = 0.5;

    let rt = Arc::new(Runtime::load(&cfg.artifacts_dir, &cfg.model)?);
    println!(
        "8 islands, {} params each, WAN 200 Mb/s / 150 ms, 30% uplink loss, \
         50% sign-pruned outer gradients",
        rt.manifest.config.param_count
    );

    // Reference run on a perfect fabric for comparison.
    let mut perfect = cfg.clone();
    perfect.comm.drop_prob = 0.0;
    perfect.prune_frac = 0.0;

    let faulty_report = Coordinator::new(cfg, rt.clone())?.run()?;
    let perfect_report = Coordinator::new(perfect, rt)?.run()?;

    for (name, r) in [("perfect fabric", &perfect_report), ("faulty fabric", &faulty_report)] {
        let m = &r.metrics;
        println!(
            "\n[{name}] final ppl {:.3} | {:.2} MB across fabric | \
             {} msgs ({} dropped) | sim comm time {:.2}s",
            m.final_ppl(),
            m.comm_bytes as f64 / 1e6,
            m.comm_messages,
            m.comm_dropped,
            m.sim_comm_seconds
        );
        let worst = r
            .drops_per_worker
            .iter()
            .enumerate()
            .max_by_key(|(_, &d)| d)
            .map(|(i, &d)| format!("island {i} lost {d} rounds"))
            .unwrap_or_default();
        println!("[{name}] {worst}");
    }

    let degradation = 100.0
        * (faulty_report.metrics.final_ppl() - perfect_report.metrics.final_ppl())
        / perfect_report.metrics.final_ppl();
    println!(
        "\nquality cost of 30% drops + 50% pruning on a slow WAN: {degradation:+.2}% PPL \
         (paper: ~2% at 50% drops; ~0.4% at 50% pruning)"
    );
    Ok(())
}
