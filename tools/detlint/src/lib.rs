//! detlint — static enforcement of the DESIGN.md §15 determinism
//! contract over `rust/src`.
//!
//! The contract (informally: "same config + seed ⇒ bitwise-identical
//! trace") is only as strong as its weakest source line. detlint walks
//! the crate with `syn` and flags the constructs that historically
//! break bitwise reproducibility:
//!
//! - **D1** `map_iter` — HashMap/HashSet iteration in deterministic
//!   zones (unordered order escapes into state).
//! - **D2** `wall_clock` — `Instant::now` / `SystemTime` / process /
//!   thread identity reads in deterministic zones.
//! - **D3** `rng_entry` — any entropy source other than the seeded
//!   `util::rng::Rng` streams (global rule, all zones).
//! - **D4** `float_fold` — float `sum`/`fold` reductions outside the
//!   audited kernels (summation order is part of the contract).
//! - **D5** `safety_comment` — `unsafe` without `// SAFETY:` (global).
//! - **D6** `lossy_cast` — lossy float casts in wire/billing code
//!   outside `comm/codec.rs` (byte accounting must be exact).
//!
//! False positives are answered in-place:
//! `// detlint: allow(<rule>, <reason>)` — the reason is mandatory.

pub mod diag;
pub mod pragma;
pub mod rules;
pub mod zones;

pub use diag::{render_json, Diagnostic};
pub use rules::{analyze_source, FileReport};
pub use zones::{zone_of, Zone};

use std::path::{Path, PathBuf};

/// Whole-tree analysis result.
#[derive(Debug, Default)]
pub struct Analysis {
    /// All violations across the tree, sorted by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
    /// Non-fatal notes (unused pragmas).
    pub notes: Vec<String>,
    /// Number of `.rs` files parsed.
    pub files_scanned: usize,
}

impl Analysis {
    /// Nonzero-exit condition.
    pub fn has_violations(&self) -> bool {
        !self.diagnostics.is_empty()
    }
}

/// Collect `.rs` files under `root`, sorted, so runs are reproducible.
fn collect_rs_files(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let rd = std::fs::read_dir(&dir)
            .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        for entry in rd {
            let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Analyze every `.rs` file under `root` (typically `rust/src`).
pub fn analyze_root(root: &Path) -> Result<Analysis, String> {
    let files = collect_rs_files(root)?;
    let mut analysis = Analysis::default();
    for path in files {
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let report = analyze_source(&rel, &src)?;
        analysis.diagnostics.extend(report.diagnostics);
        analysis.notes.extend(report.notes);
        analysis.files_scanned += 1;
    }
    analysis.diagnostics.sort();
    analysis.notes.sort();
    Ok(analysis)
}
