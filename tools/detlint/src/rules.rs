//! The D1–D6 rule visitors.
//!
//! Strategy: parse with `syn` (feature `span-locations` gives real
//! line/column spans), walk the AST, and — because `syn` discards
//! comments — cross-reference raw source lines for `// SAFETY:` blocks
//! and `// detlint: allow(...)` pragmas. Heuristics are deliberately
//! conservative-and-textual (receiver/statement source text) rather
//! than type-resolved: detlint is a contract tripwire, not a compiler,
//! and a false positive is answered with a pragma carrying a reason.

use crate::diag::Diagnostic;
use crate::pragma::{self, rule_name};
use crate::zones::{zone_of, Zone};
use proc_macro2::Span;
use std::collections::BTreeSet;
use syn::spanned::Spanned;
use syn::visit::{self, Visit};

/// Per-file analysis result.
#[derive(Debug, Default)]
pub struct FileReport {
    /// Violations (post pragma suppression), sorted.
    pub diagnostics: Vec<Diagnostic>,
    /// Non-fatal notes (unused pragmas).
    pub notes: Vec<String>,
}

/// Fixture files can pin their pseudo-location with a
/// `// detlint-fixture-path: <rel path>` header so the corpus exercises
/// zone/path scoping without living inside `rust/src`.
const FIXTURE_PATH_MARKER: &str = "detlint-fixture-path:";

/// Analyze one file's source text. `rel_path` is relative to the
/// scanned root (used for zone + path-scoped rules unless the fixture
/// header overrides it).
pub fn analyze_source(rel_path: &str, src: &str) -> Result<FileReport, String> {
    let file: syn::File = syn::parse_file(src)
        .map_err(|e| format!("{rel_path}: parse error: {e}"))?;
    let lines: Vec<&str> = src.lines().collect();

    let effective = lines
        .iter()
        .take(10)
        .find_map(|l| {
            l.find(FIXTURE_PATH_MARKER)
                .map(|p| l[p + FIXTURE_PATH_MARKER.len()..].trim().to_string())
        })
        .unwrap_or_else(|| rel_path.replace('\\', "/"));
    let zone = zone_of(&effective);

    // File-level declarations (struct fields, consts, statics) feed the
    // ident→type heuristics everywhere in the file; fn-local decls are
    // pushed/popped per function by the rule visitor.
    let mut file_decls = DeclCollector::new(&lines);
    file_decls.visit_file(&file);

    let mut v = RuleVisitor {
        lines: &lines,
        effective_path: effective.clone(),
        zone,
        maps: file_decls.maps,
        floats: file_decls.floats,
        stmt_stack: Vec::new(),
        raw: Vec::new(),
    };
    v.visit_file(&file);
    let mut raw = v.raw;

    // Pragmas: parse, suppress, flag malformed, note unused.
    let (mut pragmas, malformed) = pragma::collect(&lines);
    for m in malformed {
        raw.push(RawDiag {
            line: m.line,
            column: 0,
            rule: "P0",
            message: m.why,
        });
    }
    let mut report = FileReport::default();
    'diag: for d in raw {
        if d.rule != "P0" {
            for p in pragmas.iter_mut() {
                if p.rule == d.rule && pragma::covers(&lines, p.line, d.line) {
                    p.used = true;
                    continue 'diag;
                }
            }
        }
        report.diagnostics.push(Diagnostic {
            file: effective.clone(),
            line: d.line,
            column: d.column,
            rule: d.rule,
            name: rule_name(d.rule),
            zone: zone.label(),
            message: d.message,
        });
    }
    for p in pragmas.iter().filter(|p| !p.used) {
        report.notes.push(format!(
            "{}:{}: unused pragma allow({}, {}) — nothing to suppress here",
            effective, p.line, p.rule, p.reason
        ));
    }
    report.diagnostics.sort();
    Ok(report)
}

struct RawDiag {
    line: usize,
    column: usize,
    rule: &'static str,
    message: String,
}

/// Slice the raw source text covered by a span (columns are char
/// offsets per proc-macro2's span-locations contract).
fn span_text(lines: &[&str], span: Span) -> String {
    let (s, e) = (span.start(), span.end());
    if s.line == 0 || s.line > lines.len() || e.line > lines.len() {
        return String::new();
    }
    let char_slice = |l: &str, from: usize, to: Option<usize>| -> String {
        let it = l.chars().skip(from);
        match to {
            Some(t) => it.take(t.saturating_sub(from)).collect(),
            None => it.collect(),
        }
    };
    if s.line == e.line {
        return char_slice(lines[s.line - 1], s.column, Some(e.column));
    }
    let mut out = char_slice(lines[s.line - 1], s.column, None);
    for l in &lines[s.line..e.line - 1] {
        out.push('\n');
        out.push_str(l);
    }
    out.push('\n');
    out.push_str(&char_slice(lines[e.line - 1], 0, Some(e.column)));
    out
}

/// Word-boundary search: does `text` mention `ident` as a whole word?
fn mentions_ident(text: &str, ident: &str) -> bool {
    let bytes = text.as_bytes();
    let mut start = 0;
    while let Some(pos) = text[start..].find(ident) {
        let at = start + pos;
        let before_ok = at == 0 || {
            let c = bytes[at - 1] as char;
            !(c.is_ascii_alphanumeric() || c == '_')
        };
        let after = at + ident.len();
        let after_ok = after >= bytes.len() || {
            let c = bytes[after] as char;
            !(c.is_ascii_alphanumeric() || c == '_')
        };
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

/// Floating-point literal detector over a source snippet: a digit
/// immediately followed by `.` followed by a digit (so `1.0` matches
/// but `xs.iter` and `8` don't), or an f32/f64 suffix.
fn has_float_literal(text: &str) -> bool {
    let b = text.as_bytes();
    for i in 1..b.len().saturating_sub(1) {
        if b[i] == b'.' && b[i - 1].is_ascii_digit() && b[i + 1].is_ascii_digit() {
            return true;
        }
    }
    text.contains("f32") || text.contains("f64")
}

/// Attribute-based skip: test modules/functions and loom-only code are
/// out of contract scope. Doc comments (which syn models as `#[doc]`
/// attributes) never trigger the skip.
fn skip_attrs(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        if a.path().is_ident("doc") {
            return false;
        }
        let t = quote::ToTokens::to_token_stream(a).to_string();
        t.contains("test") || t.contains("loom")
    })
}

/// Collects in-scope idents whose declared type (or initializer) marks
/// them as hash containers or float containers. Textual on purpose.
struct DeclCollector<'s> {
    lines: &'s [&'s str],
    maps: BTreeSet<String>,
    floats: BTreeSet<String>,
}

impl<'s> DeclCollector<'s> {
    fn new(lines: &'s [&'s str]) -> Self {
        DeclCollector {
            lines,
            maps: BTreeSet::new(),
            floats: BTreeSet::new(),
        }
    }

    fn record(&mut self, ident: &str, ty_text: &str) {
        if ty_text.contains("HashMap") || ty_text.contains("HashSet") {
            self.maps.insert(ident.to_string());
        }
        // Float *containers* only (slices/vecs/arrays) — a scalar f64
        // local doesn't make `x.iter()` meaningful.
        if (ty_text.contains("f32") || ty_text.contains("f64"))
            && (ty_text.contains("Vec") || ty_text.contains('['))
        {
            self.floats.insert(ident.to_string());
        }
    }

    fn pat_idents(pat: &syn::Pat, out: &mut Vec<String>) {
        match pat {
            syn::Pat::Ident(p) => out.push(p.ident.to_string()),
            syn::Pat::Tuple(t) => {
                for e in &t.elems {
                    Self::pat_idents(e, out);
                }
            }
            syn::Pat::Reference(r) => Self::pat_idents(&r.pat, out),
            syn::Pat::Type(t) => Self::pat_idents(&t.pat, out),
            _ => {}
        }
    }
}

impl<'ast, 's> Visit<'ast> for DeclCollector<'s> {
    fn visit_local(&mut self, node: &'ast syn::Local) {
        let mut idents = Vec::new();
        Self::pat_idents(&node.pat, &mut idents);
        // Type source: explicit annotation if present, else the
        // initializer text (catches `let m = HashMap::new()`).
        let ty_text = match &node.pat {
            syn::Pat::Type(t) => span_text(self.lines, t.ty.span()),
            _ => node
                .init
                .as_ref()
                .map(|i| span_text(self.lines, i.expr.span()))
                .unwrap_or_default(),
        };
        for id in idents {
            self.record(&id, &ty_text);
        }
        visit::visit_local(self, node);
    }

    fn visit_pat_type(&mut self, node: &'ast syn::PatType) {
        // Fn params and closure params with annotations.
        let mut idents = Vec::new();
        Self::pat_idents(&node.pat, &mut idents);
        let ty_text = span_text(self.lines, node.ty.span());
        for id in idents {
            self.record(&id, &ty_text);
        }
        visit::visit_pat_type(self, node);
    }

    fn visit_field(&mut self, node: &'ast syn::Field) {
        if let Some(id) = &node.ident {
            let ty_text = span_text(self.lines, node.ty.span());
            self.record(&id.to_string(), &ty_text);
        }
        visit::visit_field(self, node);
    }
}

const MAP_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "drain",
    "retain",
];

/// Statement-level sinks that impose a total order (or reduce to an
/// order-free scalar) on a map iteration, exempting it from D1.
const ORDER_SINKS: &[&str] = &["sort", "max_by", "min_by", "BTreeMap", "BTreeSet", ".count()"];

const D2_PATTERNS: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "process::id",
    "thread::current",
    "ThreadId",
];

const INT_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

struct RuleVisitor<'s> {
    lines: &'s [&'s str],
    effective_path: String,
    zone: Zone,
    maps: BTreeSet<String>,
    floats: BTreeSet<String>,
    stmt_stack: Vec<Span>,
    raw: Vec<RawDiag>,
}

impl<'s> RuleVisitor<'s> {
    fn emit(&mut self, span: Span, rule: &'static str, message: String) {
        self.raw.push(RawDiag {
            line: span.start().line,
            column: span.start().column,
            rule,
            message,
        });
    }

    /// Anchor span for expression-level rules: the innermost enclosing
    /// statement's start, so a pragma placed above a (possibly
    /// multi-line) statement covers every finding inside it.
    fn anchor(&self, fallback: Span) -> Span {
        self.stmt_stack.last().copied().unwrap_or(fallback)
    }

    /// Source text of the innermost enclosing statement.
    fn stmt_text(&self) -> String {
        self.stmt_stack
            .last()
            .map(|s| span_text(self.lines, *s))
            .unwrap_or_default()
    }

    fn stmt_has_order_sink(&self) -> bool {
        let t = self.stmt_text();
        ORDER_SINKS.iter().any(|s| t.contains(s))
    }

    fn is_map_expr(&self, text: &str) -> bool {
        text.contains("HashMap::")
            || text.contains("HashSet::")
            || self.maps.iter().any(|m| mentions_ident(text, m))
    }

    /// D4 audit list: the two files allowed to own float reductions.
    fn is_audited_float_file(&self) -> bool {
        self.effective_path == "util/math.rs" || self.effective_path == "coordinator/average.rs"
    }

    /// D3 exemption: the seeded RNG implementation itself.
    fn is_rng_file(&self) -> bool {
        self.effective_path == "util/rng.rs"
    }

    /// D6 scope: wire/billing code = `comm/**` except the audited codec.
    fn in_wire_scope(&self) -> bool {
        self.effective_path.starts_with("comm/") && !self.effective_path.ends_with("codec.rs")
    }

    /// A contiguous run of `//` comment lines (attributes allowed in
    /// between) directly above `line` containing "SAFETY:", or the
    /// declaration line itself carrying it.
    fn has_safety_comment(&self, line: usize) -> bool {
        if line == 0 || line > self.lines.len() {
            return false;
        }
        if self.lines[line - 1].contains("SAFETY:") {
            return true;
        }
        let mut l = line - 1; // 1-based line above the decl
        while l >= 1 {
            let t = self.lines[l - 1].trim_start();
            if t.starts_with("//") {
                if t.contains("SAFETY:") {
                    return true;
                }
                l -= 1;
            } else if t.starts_with("#[") || t.starts_with("#!") {
                l -= 1; // see through attributes between comment and item
            } else {
                return false;
            }
        }
        false
    }

}

impl<'ast, 's> Visit<'ast> for RuleVisitor<'s> {
    fn visit_stmt(&mut self, node: &'ast syn::Stmt) {
        self.stmt_stack.push(node.span());
        visit::visit_stmt(self, node);
        self.stmt_stack.pop();
    }

    fn visit_item_mod(&mut self, node: &'ast syn::ItemMod) {
        if skip_attrs(&node.attrs) {
            return;
        }
        visit::visit_item_mod(self, node);
    }

    fn visit_item_fn(&mut self, node: &'ast syn::ItemFn) {
        if skip_attrs(&node.attrs) {
            return;
        }
        // Function-scoped decls (params, lets) extend the file-level
        // ident sets for the duration of this body, then roll back.
        let saved_maps = self.maps.clone();
        let saved_floats = self.floats.clone();
        let mut dc = DeclCollector::new(self.lines);
        dc.visit_item_fn(node);
        self.maps.extend(dc.maps);
        self.floats.extend(dc.floats);
        visit::visit_item_fn(self, node);
        self.maps = saved_maps;
        self.floats = saved_floats;
    }

    fn visit_impl_item_fn(&mut self, node: &'ast syn::ImplItemFn) {
        if skip_attrs(&node.attrs) {
            return;
        }
        let saved_maps = self.maps.clone();
        let saved_floats = self.floats.clone();
        let mut dc = DeclCollector::new(self.lines);
        dc.visit_impl_item_fn(node);
        self.maps.extend(dc.maps);
        self.floats.extend(dc.floats);
        visit::visit_impl_item_fn(self, node);
        self.maps = saved_maps;
        self.floats = saved_floats;
    }

    fn visit_item_impl(&mut self, node: &'ast syn::ItemImpl) {
        if skip_attrs(&node.attrs) {
            return;
        }
        // D5 half two: `unsafe impl` needs a SAFETY comment.
        if let Some(tok) = &node.unsafety {
            let line = tok.span.start().line;
            if !self.has_safety_comment(line) {
                self.emit(
                    tok.span,
                    "D5",
                    "`unsafe impl` without an immediately-preceding `// SAFETY:` justification"
                        .to_string(),
                );
            }
        }
        visit::visit_item_impl(self, node);
    }

    fn visit_expr_unsafe(&mut self, node: &'ast syn::ExprUnsafe) {
        // D5 half one: every unsafe block carries its proof obligation.
        let line = node.unsafe_token.span.start().line;
        if !self.has_safety_comment(line) {
            self.emit(
                node.unsafe_token.span,
                "D5",
                "`unsafe` block without an immediately-preceding `// SAFETY:` comment".to_string(),
            );
        }
        visit::visit_expr_unsafe(self, node);
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        let method = node.method.to_string();
        let recv = span_text(self.lines, node.receiver.span());

        // D1: unordered hash iteration in a deterministic zone.
        if self.zone.is_deterministic()
            && MAP_ITER_METHODS.contains(&method.as_str())
            && self.is_map_expr(&recv)
            && !self.stmt_has_order_sink()
        {
            self.emit(
                self.anchor(node.method.span()),
                "D1",
                format!(
                    "HashMap/HashSet `.{method}()` in a deterministic zone: iteration order is \
                     unordered; use BTreeMap/BTreeSet, impose a total order (sort/max_by), or \
                     pragma with a commutativity argument"
                ),
            );
        }

        // D4: float reductions outside the audited kernels.
        if self.zone.is_deterministic() && !self.is_audited_float_file() {
            if method == "sum" || method == "product" {
                let is_float = match &node.turbofish {
                    Some(tf) => {
                        let t = span_text(self.lines, tf.span());
                        t.contains("f32") || t.contains("f64")
                    }
                    None => {
                        let stmt = self.stmt_text();
                        stmt.contains("f32")
                            || stmt.contains("f64")
                            || self.floats.iter().any(|f| mentions_ident(&recv, f))
                    }
                };
                if is_float {
                    self.emit(
                        self.anchor(node.method.span()),
                        "D4",
                        format!(
                            "float `.{method}()` reduction outside util/math.rs / \
                             coordinator/average.rs: route through the audited kernels \
                             (math::sum_f64 / sum_as_f64) so summation order stays pinned"
                        ),
                    );
                }
            } else if method == "fold" && node.args.len() == 2 {
                let mut args = node.args.iter();
                let init = span_text(self.lines, args.next().unwrap().span());
                let body = span_text(self.lines, args.next().unwrap().span());
                let float_init = has_float_literal(&init) || init.contains("INFINITY");
                let min_max = body.contains(".max(")
                    || body.contains(".min(")
                    || body.contains("::max")
                    || body.contains("::min");
                if float_init && !min_max {
                    self.emit(
                        self.anchor(node.method.span()),
                        "D4",
                        "float `.fold()` reduction outside the audited kernels: only \
                         order-insensitive min/max folds are exempt"
                            .to_string(),
                    );
                }
            }
        }

        visit::visit_expr_method_call(self, node);
    }

    fn visit_expr_for_loop(&mut self, node: &'ast syn::ExprForLoop) {
        // D1, for-loop form: `for (k, v) in map { ... }`.
        if self.zone.is_deterministic() {
            let it = span_text(self.lines, node.expr.span());
            if self.is_map_expr(&it) && !self.stmt_has_order_sink() {
                self.emit(
                    self.anchor(node.for_token.span),
                    "D1",
                    "for-loop over a HashMap/HashSet in a deterministic zone: iteration order \
                     is unordered; sort first or pragma with a commutativity argument"
                        .to_string(),
                );
            }
        }
        visit::visit_expr_for_loop(self, node);
    }

    fn visit_expr_path(&mut self, node: &'ast syn::ExprPath) {
        let p = span_text(self.lines, node.span());

        // D2: ambient time / process / thread identity in det zones.
        if self.zone.is_deterministic() {
            if let Some(pat) = D2_PATTERNS.iter().find(|pat| p.contains(*pat)) {
                self.emit(
                    self.anchor(node.span()),
                    "D2",
                    format!(
                        "`{pat}` read in a deterministic zone: wall-clock/ambient identity must \
                         not influence deterministic state (move to a wall-clock zone or pragma \
                         with proof it only feeds timing columns)"
                    ),
                );
            }
        }

        // D3 (global): the only entropy source is util::rng::Rng.
        if !self.is_rng_file()
            && (p.starts_with("rand::") || p.contains("RandomState") || p.contains("DefaultHasher"))
        {
            self.emit(
                self.anchor(node.span()),
                "D3",
                "ambient RNG/hasher entry point: all randomness must derive from the seeded \
                 util::rng::Rng streams"
                    .to_string(),
            );
        }

        visit::visit_expr_path(self, node);
    }

    fn visit_item_use(&mut self, node: &'ast syn::ItemUse) {
        if skip_attrs(&node.attrs) {
            return;
        }
        // D3 on imports, so `use rand::Rng` is caught even before use.
        let t = span_text(self.lines, node.span());
        if !self.is_rng_file()
            && (t.contains(" rand::")
                || t.contains(" rand;")
                || t.contains("RandomState")
                || t.contains("DefaultHasher"))
        {
            self.emit(
                node.span(),
                "D3",
                "import of an ambient RNG/hasher: all randomness must derive from the seeded \
                 util::rng::Rng streams"
                    .to_string(),
            );
        }
        visit::visit_item_use(self, node);
    }

    fn visit_expr_cast(&mut self, node: &'ast syn::ExprCast) {
        // D6: lossy float casts in wire/billing code outside codec.rs.
        if self.in_wire_scope() {
            let ty = span_text(self.lines, node.ty.span());
            let ty = ty.trim();
            if ty == "f32" {
                self.emit(
                    self.anchor(node.as_token.span),
                    "D6",
                    "`as f32` narrowing in wire/billing code outside comm/codec.rs: precision \
                     loss must live in the audited codec"
                        .to_string(),
                );
            } else if INT_TYPES.contains(&ty) {
                let operand = span_text(self.lines, node.expr.span());
                let floaty = operand.contains(".ceil()")
                    || operand.contains(".floor()")
                    || operand.contains(".round()")
                    || has_float_literal(&operand);
                if floaty {
                    self.emit(
                        self.anchor(node.as_token.span),
                        "D6",
                        format!(
                            "float-to-`{ty}` cast in wire/billing code outside comm/codec.rs: \
                             byte accounting must be integer-exact (or pragma with a range proof)"
                        ),
                    );
                }
            }
        }
        visit::visit_expr_cast(self, node);
    }
}
