//! Diagnostic records and their human / JSON renderings.

/// One finding. Sorts by (file, line, column, rule) so output order is
/// deterministic — the linter holds itself to its own contract.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    /// Path relative to the scanned root (fixture-path override applied).
    pub file: String,
    /// 1-based line of the offending expression/item.
    pub line: usize,
    /// 0-based UTF-8 column.
    pub column: usize,
    /// Rule id: "D1".."D6", or "P0" for a malformed pragma.
    pub rule: &'static str,
    /// Stable rule name usable in `detlint: allow(<name>, ...)`.
    pub name: &'static str,
    /// Zone label of the file ("deterministic" / "wall-clock" / "neutral").
    pub zone: &'static str,
    /// What went wrong and what the fix is.
    pub message: String,
}

impl Diagnostic {
    /// `file:line:col [D1 map_iter] (deterministic) message` — one line,
    /// grep- and editor-jump-friendly.
    pub fn render_human(&self) -> String {
        format!(
            "{}:{}:{} [{} {}] ({}) {}",
            self.file,
            self.line,
            self.column + 1,
            self.rule,
            self.name,
            self.zone,
            self.message
        )
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a findings report as a stable JSON document (sorted input
/// assumed). Schema: `{ "root", "files_scanned", "violations": [...],
/// "notes": [...] }`.
pub fn render_json(
    root: &str,
    files_scanned: usize,
    diagnostics: &[Diagnostic],
    notes: &[String],
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"root\": \"{}\",\n", json_escape(root)));
    out.push_str(&format!("  \"files_scanned\": {files_scanned},\n"));
    out.push_str(&format!("  \"violation_count\": {},\n", diagnostics.len()));
    out.push_str("  \"violations\": [\n");
    for (i, d) in diagnostics.iter().enumerate() {
        let comma = if i + 1 < diagnostics.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"file\": \"{}\", \"line\": {}, \"column\": {}, \"rule\": \"{}\", \"name\": \"{}\", \"zone\": \"{}\", \"message\": \"{}\"}}{comma}\n",
            json_escape(&d.file),
            d.line,
            d.column + 1,
            d.rule,
            d.name,
            d.zone,
            json_escape(&d.message),
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"notes\": [\n");
    for (i, n) in notes.iter().enumerate() {
        let comma = if i + 1 < notes.len() { "," } else { "" };
        out.push_str(&format!("    \"{}\"{comma}\n", json_escape(n)));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes_and_renders() {
        let d = Diagnostic {
            file: "a/b.rs".into(),
            line: 3,
            column: 4,
            rule: "D1",
            name: "map_iter",
            zone: "deterministic",
            message: "say \"no\"".into(),
        };
        let j = render_json("rust/src", 1, &[d.clone()], &[]);
        assert!(j.contains("\"rule\": \"D1\""));
        assert!(j.contains("say \\\"no\\\""));
        assert!(d.render_human().starts_with("a/b.rs:3:5 [D1 map_iter]"));
    }
}
