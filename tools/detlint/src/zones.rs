//! Zone classification: which determinism regime a source file lives in.
//!
//! Mirrors the table in DESIGN.md §15. Paths are relative to `rust/src`
//! with `/` separators (the walker normalizes `\` before calling in).

/// Determinism regime of one source file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Zone {
    /// Bitwise-reproducibility contract applies: rules D1, D2, D4 are
    /// live (plus the global rules D3, D5, D6).
    Deterministic,
    /// Wall-clock and ambient-environment reads are permitted (timing
    /// columns, benches, OS process plumbing). Only the global rules
    /// D3, D5, D6 apply.
    WallClock,
    /// Not named by the contract (pure helpers, prop-test harness).
    /// Treated like `WallClock` for rule scoping.
    Neutral,
}

impl Zone {
    /// Human label used in diagnostics and JSON output.
    pub fn label(self) -> &'static str {
        match self {
            Zone::Deterministic => "deterministic",
            Zone::WallClock => "wall-clock",
            Zone::Neutral => "neutral",
        }
    }

    /// Whether the deterministic-zone-only rules (D1, D2, D4) apply.
    pub fn is_deterministic(self) -> bool {
        matches!(self, Zone::Deterministic)
    }
}

/// Classify a file path (relative to `rust/src`) into its zone.
///
/// The longest/most-specific prefixes are checked first: `comm/tcp*`
/// is wall-clock even though `comm/` is deterministic.
pub fn zone_of(rel: &str) -> Zone {
    let rel = rel.replace('\\', "/");
    let r = rel.as_str();

    // Wall-clock carve-outs inside otherwise-deterministic trees.
    if r.starts_with("comm/tcp") {
        return Zone::WallClock;
    }

    // Deterministic zones (DESIGN.md §15 table).
    if r.starts_with("coordinator/")
        || r.starts_with("comm/")
        || r.starts_with("engine/")
        || r.starts_with("checkpoint/")
        || r.starts_with("config/")
        || r.starts_with("data/")
        || r == "coordinator.rs"
        || r == "comm.rs"
        || r == "engine.rs"
        || r == "checkpoint.rs"
        || r == "config.rs"
        || r == "data.rs"
        || r == "util/rng.rs"
        || r == "util/math.rs"
    {
        return Zone::Deterministic;
    }

    // Wall-clock-permitted zones.
    if r.starts_with("metrics/")
        || r.starts_with("bench/")
        || r.starts_with("worker/")
        || r.starts_with("runtime/")
        || r.starts_with("bin/")
        || r == "metrics.rs"
        || r == "bench.rs"
        || r == "worker.rs"
        || r == "runtime.rs"
        || r == "main.rs"
    {
        return Zone::WallClock;
    }

    Zone::Neutral
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zone_table_matches_design_doc() {
        assert_eq!(zone_of("coordinator/mod.rs"), Zone::Deterministic);
        // The robust-aggregation seam and the scripted adversary are
        // fully deterministic (PR 10): estimator selection, rejection,
        // and every attack draw are pure functions of (seed, round,
        // worker) — D1/D2/D4 stay live for them.
        assert_eq!(zone_of("coordinator/aggregate.rs"), Zone::Deterministic);
        assert_eq!(zone_of("coordinator/adversary.rs"), Zone::Deterministic);
        assert_eq!(zone_of("comm/codec.rs"), Zone::Deterministic);
        assert_eq!(zone_of("comm/tcp.rs"), Zone::WallClock);
        assert_eq!(zone_of("comm/tcp/rendezvous.rs"), Zone::WallClock);
        assert_eq!(zone_of("engine/pool.rs"), Zone::Deterministic);
        assert_eq!(zone_of("checkpoint/mod.rs"), Zone::Deterministic);
        assert_eq!(zone_of("config/mod.rs"), Zone::Deterministic);
        assert_eq!(zone_of("data/tokenizer.rs"), Zone::Deterministic);
        assert_eq!(zone_of("util/rng.rs"), Zone::Deterministic);
        assert_eq!(zone_of("util/math.rs"), Zone::Deterministic);
        assert_eq!(zone_of("metrics/mod.rs"), Zone::WallClock);
        assert_eq!(zone_of("bench/mod.rs"), Zone::WallClock);
        assert_eq!(zone_of("worker/mod.rs"), Zone::WallClock);
        assert_eq!(zone_of("runtime/mod.rs"), Zone::WallClock);
        assert_eq!(zone_of("main.rs"), Zone::WallClock);
        assert_eq!(zone_of("bin/probe.rs"), Zone::WallClock);
        assert_eq!(zone_of("lib.rs"), Zone::Neutral);
        assert_eq!(zone_of("util/json.rs"), Zone::Neutral);
        assert_eq!(zone_of("util/prop.rs"), Zone::Neutral);
    }
}
