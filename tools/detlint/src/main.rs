//! CLI: `detlint [ROOT] [--json PATH]`
//!
//! ROOT defaults to the first of `rust/src`, `../../rust/src`, `src`
//! that exists (repo root, tools/detlint, and rust/ working dirs all
//! work). Exit codes: 0 clean, 1 violations, 2 usage/IO/parse error.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn default_root() -> Option<PathBuf> {
    ["rust/src", "../../rust/src", "src"]
        .iter()
        .map(PathBuf::from)
        .find(|p| p.is_dir())
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json_out: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(PathBuf::from(p)),
                None => {
                    eprintln!("detlint: --json requires a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: detlint [ROOT] [--json PATH]");
                println!("  checks the DESIGN.md §15 determinism contract over ROOT");
                return ExitCode::SUCCESS;
            }
            other if root.is_none() && !other.starts_with('-') => {
                root = Some(PathBuf::from(other));
            }
            other => {
                eprintln!("detlint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    let Some(root) = root.or_else(default_root) else {
        eprintln!("detlint: no ROOT given and no default (rust/src) found");
        return ExitCode::from(2);
    };

    let analysis = match detlint::analyze_root(&root) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("detlint: {e}");
            return ExitCode::from(2);
        }
    };

    for d in &analysis.diagnostics {
        println!("{}", d.render_human());
    }
    for n in &analysis.notes {
        println!("note: {n}");
    }
    println!(
        "detlint: {} file(s) scanned under {}, {} violation(s), {} note(s)",
        analysis.files_scanned,
        root.display(),
        analysis.diagnostics.len(),
        analysis.notes.len()
    );

    if let Some(path) = json_out {
        let doc = detlint::render_json(
            &root.display().to_string(),
            analysis.files_scanned,
            &analysis.diagnostics,
            &analysis.notes,
        );
        if let Err(e) = write_json(&path, &doc) {
            eprintln!("detlint: write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }

    if analysis.has_violations() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn write_json(path: &Path, doc: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc)
}
