//! `// detlint: allow(<rule>, <reason>)` pragma parsing.
//!
//! A pragma suppresses diagnostics of one rule on the line it trails,
//! or — when it sits on its own line — on the first code line below it
//! (scanning across a contiguous run of comment/attribute lines, so a
//! pragma can sit above a doc comment or `#[...]` block). The reason is
//! mandatory: an allow without a why is itself a violation (P0).

/// One well-formed pragma found in a file.
#[derive(Clone, Debug)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// Canonical rule id ("D1".."D6") the pragma suppresses.
    pub rule: &'static str,
    /// Free-text justification (non-empty by construction).
    pub reason: String,
    /// Set once a diagnostic was suppressed by this pragma.
    pub used: bool,
}

/// A pragma-looking comment that doesn't parse: missing reason, unknown
/// rule name, or no closing paren.
#[derive(Clone, Debug)]
pub struct Malformed {
    pub line: usize,
    pub why: String,
}

/// Map a rule spelling (id or stable name) to its canonical id.
pub fn normalize_rule(s: &str) -> Option<&'static str> {
    match s.trim() {
        "D1" | "map_iter" => Some("D1"),
        "D2" | "wall_clock" => Some("D2"),
        "D3" | "rng_entry" => Some("D3"),
        "D4" | "float_fold" => Some("D4"),
        "D5" | "safety_comment" => Some("D5"),
        "D6" | "lossy_cast" => Some("D6"),
        _ => None,
    }
}

/// Canonical rule id → stable name (for diagnostics).
pub fn rule_name(rule: &'static str) -> &'static str {
    match rule {
        "D1" => "map_iter",
        "D2" => "wall_clock",
        "D3" => "rng_entry",
        "D4" => "float_fold",
        "D5" => "safety_comment",
        "D6" => "lossy_cast",
        _ => "pragma",
    }
}

const MARKER: &str = "detlint: allow(";

/// Scan raw source lines for pragmas. Returns (parsed, malformed).
pub fn collect(lines: &[&str]) -> (Vec<Pragma>, Vec<Malformed>) {
    let mut pragmas = Vec::new();
    let mut bad = Vec::new();
    for (idx, raw) in lines.iter().enumerate() {
        let line_no = idx + 1;
        let Some(pos) = raw.find(MARKER) else {
            // Catch near-miss spellings so they don't silently no-op.
            if raw.contains("detlint:") && raw.contains("allow") {
                bad.push(Malformed {
                    line: line_no,
                    why: "pragma syntax is `// detlint: allow(<rule>, <reason>)`".into(),
                });
            }
            continue;
        };
        let body = &raw[pos + MARKER.len()..];
        let Some(close) = body.rfind(')') else {
            bad.push(Malformed {
                line: line_no,
                why: "unterminated pragma: missing `)`".into(),
            });
            continue;
        };
        let inner = &body[..close];
        let Some((rule_txt, reason)) = inner.split_once(',') else {
            bad.push(Malformed {
                line: line_no,
                why: "pragma needs a reason: `allow(<rule>, <reason>)`".into(),
            });
            continue;
        };
        let Some(rule) = normalize_rule(rule_txt) else {
            bad.push(Malformed {
                line: line_no,
                why: format!(
                    "unknown rule `{}` (use D1-D6 or map_iter/wall_clock/rng_entry/float_fold/safety_comment/lossy_cast)",
                    rule_txt.trim()
                ),
            });
            continue;
        };
        let reason = reason.trim().to_string();
        if reason.is_empty() {
            bad.push(Malformed {
                line: line_no,
                why: "pragma reason must be non-empty".into(),
            });
            continue;
        }
        pragmas.push(Pragma {
            line: line_no,
            rule,
            reason,
            used: false,
        });
    }
    (pragmas, bad)
}

/// True when `line` (1-based) is a comment or attribute line — the kind
/// a pragma is allowed to "see through" when scanning downward/upward.
pub fn is_comment_or_attr(lines: &[&str], line: usize) -> bool {
    if line == 0 || line > lines.len() {
        return false;
    }
    let t = lines[line - 1].trim_start();
    t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!") || t.is_empty()
}

/// Does a pragma at `pragma_line` cover a diagnostic at `diag_line`?
///
/// Coverage: same line (trailing pragma), or the pragma sits above with
/// only comment/attribute/blank lines in between.
pub fn covers(lines: &[&str], pragma_line: usize, diag_line: usize) -> bool {
    if pragma_line == diag_line {
        return true;
    }
    if pragma_line > diag_line {
        return false;
    }
    ((pragma_line + 1)..diag_line).all(|l| is_comment_or_attr(lines, l))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_well_formed_pragma() {
        let src = ["let x = 1;", "// detlint: allow(map_iter, commutative sum)"];
        let (ps, bad) = collect(&src);
        assert_eq!(ps.len(), 1);
        assert!(bad.is_empty());
        assert_eq!(ps[0].rule, "D1");
        assert_eq!(ps[0].reason, "commutative sum");
        assert_eq!(ps[0].line, 2);
    }

    #[test]
    fn reason_is_mandatory_and_rule_must_exist() {
        let src = [
            "// detlint: allow(map_iter)",
            "// detlint: allow(D9, because)",
            "// detlint: allow(wall_clock,   )",
        ];
        let (ps, bad) = collect(&src);
        assert!(ps.is_empty());
        assert_eq!(bad.len(), 3);
    }

    #[test]
    fn coverage_sees_through_comment_blocks() {
        let src = [
            "// detlint: allow(D4, pinned by golden trace)",
            "// an unrelated comment",
            "#[inline]",
            "let s: f32 = xs.iter().sum();",
        ];
        assert!(covers(&src, 1, 4));
        assert!(covers(&src, 1, 1));
        assert!(!covers(&src, 4, 1));
    }

    #[test]
    fn coverage_stops_at_code() {
        let src = [
            "// detlint: allow(D1, benign)",
            "let a = 1;",
            "let b: Vec<_> = m.keys().collect();",
        ];
        assert!(!covers(&src, 1, 3));
    }
}
