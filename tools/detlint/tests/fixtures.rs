//! Fixture-corpus tests: every rule must fire on its known-bad fixture
//! (and only there), pragmas and sinks must suppress, zone scoping must
//! hold, and — the acceptance gate — the real crate under rust/src must
//! be violation-free.

use std::fs;
use std::path::{Path, PathBuf};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

fn read_fixture(name: &str) -> String {
    let path = fixture_dir().join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn rules_for(name: &str) -> Vec<&'static str> {
    let src = read_fixture(name);
    let rep = detlint::analyze_source(name, &src).unwrap();
    rep.diagnostics.iter().map(|d| d.rule).collect()
}

#[test]
fn d1_flags_exactly_the_unordered_iterations() {
    assert_eq!(rules_for("d1_map_iter.rs"), ["D1", "D1"]);
}

#[test]
fn d2_flags_exactly_the_ambient_time_reads() {
    assert_eq!(rules_for("d2_wall_clock.rs"), ["D2", "D2"]);
}

#[test]
fn d3_flags_entropy_in_all_zones() {
    assert_eq!(rules_for("d3_rng.rs"), ["D3", "D3", "D3", "D3"]);
}

#[test]
fn d4_flags_unaudited_float_reductions() {
    assert_eq!(rules_for("d4_float_fold.rs"), ["D4", "D4", "D4"]);
}

#[test]
fn d5_flags_undocumented_unsafe() {
    assert_eq!(rules_for("d5_unsafe.rs"), ["D5", "D5"]);
}

#[test]
fn d6_flags_lossy_wire_casts() {
    assert_eq!(rules_for("d6_lossy_cast.rs"), ["D6", "D6"]);
}

#[test]
fn malformed_pragmas_are_violations() {
    assert_eq!(rules_for("bad_pragma.rs"), ["P0", "P0"]);
}

#[test]
fn clean_fixture_is_clean() {
    let src = read_fixture("clean.rs");
    let rep = detlint::analyze_source("clean.rs", &src).unwrap();
    assert!(rep.diagnostics.is_empty(), "{:?}", rep.diagnostics);
    assert!(rep.notes.is_empty(), "{:?}", rep.notes);
}

#[test]
fn d1_is_zone_scoped_wall_clock_is_exempt() {
    // The same source, re-declared into the wall-clock `runtime` zone,
    // must produce no D1 findings (only the global rules apply there).
    let src = read_fixture("d1_map_iter.rs");
    let moved = src.replace("coordinator/fixture_d1.rs", "runtime/fixture_d1.rs");
    let rep = detlint::analyze_source("d1_map_iter.rs", &moved).unwrap();
    assert!(rep.diagnostics.is_empty(), "{:?}", rep.diagnostics);
    // ... and the now-pointless pragma is reported as unused.
    assert_eq!(rep.notes.len(), 1, "{:?}", rep.notes);
}

#[test]
fn corpus_as_a_tree_exits_nonzero() {
    let analysis = detlint::analyze_root(&fixture_dir()).unwrap();
    assert!(analysis.has_violations());
    assert!(analysis.files_scanned >= 8);
    // 2+2+4+3+2+2 rule findings + 2 malformed pragmas.
    assert_eq!(analysis.diagnostics.len(), 17, "{:#?}", analysis.diagnostics);
}

#[test]
fn diagnostics_carry_location_rule_and_zone() {
    let src = read_fixture("d1_map_iter.rs");
    let rep = detlint::analyze_source("d1_map_iter.rs", &src).unwrap();
    let d = &rep.diagnostics[0];
    assert_eq!(d.file, "coordinator/fixture_d1.rs");
    assert_eq!(d.zone, "deterministic");
    assert_eq!(d.name, "map_iter");
    assert!(d.line > 1);
    let json = detlint::render_json("fixtures", 1, &rep.diagnostics, &rep.notes);
    assert!(json.contains("\"rule\": \"D1\""));
    assert!(json.contains("\"zone\": \"deterministic\""));
}

/// The acceptance gate: detlint exits 0 on the full crate. Every
/// legacy violation is either fixed or carries a reasoned pragma.
#[test]
fn full_crate_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("..")
        .join("rust")
        .join("src");
    if !root.is_dir() {
        eprintln!("skipping full_crate_is_clean: {} not found", root.display());
        return;
    }
    let analysis = detlint::analyze_root(&root).unwrap();
    assert!(analysis.files_scanned > 20, "suspiciously few files scanned");
    let rendered: Vec<String> = analysis
        .diagnostics
        .iter()
        .map(|d| d.render_human())
        .collect();
    assert!(
        analysis.diagnostics.is_empty(),
        "determinism contract violations in rust/src:\n{}",
        rendered.join("\n")
    );
}
