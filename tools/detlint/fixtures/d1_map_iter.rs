// detlint-fixture-path: coordinator/fixture_d1.rs
//! D1 fixture: unordered HashMap/HashSet iteration in a deterministic
//! zone. Expected findings: exactly 2 × D1 (the first two functions).

use std::collections::HashMap;

pub fn leaks_arbitrary_order(m: &HashMap<String, u64>) -> Vec<String> {
    m.keys().cloned().collect()
}

pub fn for_loop_over_map(m: HashMap<String, u64>) -> Vec<u64> {
    let mut out = Vec::new();
    for (_k, v) in m {
        out.push(v);
    }
    out
}

pub fn exempt_total_order_sink(m: &HashMap<String, u64>) -> Option<&String> {
    m.keys().max_by(|a, b| a.cmp(b))
}

pub fn pragma_documented(m: &HashMap<String, u64>) -> u64 {
    let mut acc = 0u64;
    // detlint: allow(map_iter, commutative integer accumulation; order unobservable)
    for v in m.values() {
        acc += v;
    }
    acc
}
