// detlint-fixture-path: coordinator/fixture_d2.rs
//! D2 fixture: ambient time / process identity reads in a
//! deterministic zone. Expected findings: exactly 2 × D2.

use std::time::Instant;

pub fn timestamped_decision() -> bool {
    let t0 = Instant::now();
    t0.elapsed().as_nanos() % 2 == 0
}

pub fn process_keyed_seed() -> u64 {
    u64::from(std::process::id())
}

pub fn pragma_timing_column(acc: &mut f64) {
    // detlint: allow(wall_clock, feeds a reporting-only timing column; never model state)
    let t0 = Instant::now();
    *acc += t0.elapsed().as_secs_f64();
}
