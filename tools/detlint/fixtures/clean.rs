// detlint-fixture-path: coordinator/fixture_clean.rs
//! Clean fixture: deterministic-zone code with nothing to flag —
//! ordered containers, integer reductions, exact casts.

use std::collections::BTreeMap;

pub fn ordered_total(m: &BTreeMap<String, u64>) -> u64 {
    m.values().sum()
}

pub fn int_mean(xs: &[u64]) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    xs.iter().sum::<u64>() / xs.len() as u64
}
