// detlint-fixture-path: coordinator/fixture_bad_pragma.rs
//! P0 fixture: pragma-looking comments that don't parse are themselves
//! violations — an allow without a why is not an allow. Expected
//! findings: exactly 2 × P0.

pub fn no_reason() -> u64 {
    // detlint: allow(map_iter)
    7
}

pub fn unknown_rule() -> u64 {
    // detlint: allow(D9, because I said so)
    9
}
