// detlint-fixture-path: util/fixture_d5.rs
//! D5 fixture: `unsafe` without a SAFETY justification — a global rule,
//! checked in every zone (this file is zone-neutral). Expected
//! findings: exactly 2 × D5.

pub struct RawHandle(pub *mut u8);

unsafe impl Send for RawHandle {}

pub fn peek(p: *const u8) -> u8 {
    unsafe { *p }
}

// SAFETY: shared reads only — the pointee is never mutated through a
// shared RawHandle, so concurrent &RawHandle use cannot race.
unsafe impl Sync for RawHandle {}

pub fn peek_documented(p: *const u8) -> u8 {
    // SAFETY: fixture contract — the caller guarantees `p` is valid
    // for reads and properly aligned.
    unsafe { *p }
}
