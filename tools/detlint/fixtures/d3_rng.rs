// detlint-fixture-path: worker/fixture_d3.rs
//! D3 fixture: ambient entropy is banned in *every* zone — this file
//! sits in the wall-clock `worker` zone on purpose. Expected findings:
//! exactly 4 × D3 (two imports, two expressions).

use rand::Rng as _;
use std::collections::hash_map::RandomState;

pub fn ambient_entropy() -> u64 {
    rand::random::<u64>()
}

pub fn hasher_entropy() -> RandomState {
    RandomState::new()
}
