// detlint-fixture-path: engine/fixture_d4.rs
//! D4 fixture: float reductions outside the audited kernels
//! (util/math.rs, coordinator/average.rs). Expected findings: exactly
//! 3 × D4 (field-typed sum, turbofish sum, non-minmax fold).

pub struct Report {
    pub per_worker_s: Vec<f64>,
}

impl Report {
    pub fn total(&self) -> f64 {
        self.per_worker_s.iter().sum()
    }
}

pub fn unaudited_float_total(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}

pub fn running_product(xs: &[f64]) -> f64 {
    xs.iter().fold(1.0, |acc, &x| acc * x)
}

pub fn exempt_max_fold(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0, |acc, &x| acc.max(x))
}

pub fn exempt_integer_total(ns: &[u64]) -> u64 {
    ns.iter().sum()
}
