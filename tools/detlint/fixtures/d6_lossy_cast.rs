// detlint-fixture-path: comm/fixture_d6.rs
//! D6 fixture: lossy float casts in wire/billing code outside
//! comm/codec.rs. Expected findings: exactly 2 × D6.

pub fn billed_bytes(elems: usize, density: f64) -> u64 {
    (elems as f64 * density) as u64
}

pub fn narrowed(x: f64) -> f32 {
    x as f32
}

pub fn exempt_integer_widen(n: u32) -> u64 {
    u64::from(n)
}

pub fn exempt_index(n: usize) -> u64 {
    n as u64
}

pub fn pragma_byte_ceiling(bits: usize) -> u64 {
    // detlint: allow(lossy_cast, exact below 2^53 bits; ceil of n/8 is integral)
    ((bits as f64) / 8.0).ceil() as u64
}
